"""Fault-tolerant topology service: admission, cache, deadline ladder and
the fault-injection harness (DESIGN.md §15)."""
import time

import numpy as np
import pytest

from repro.core.api import BATopoConfig, optimize_topology
from repro.core.graph import Topology
from repro.core.guard import SolveFailure, SolveOutcome, check_invariants
from repro.core.reopt import DriftPolicy
from repro.serve.topo_service import (
    ServiceHooks, ServicePolicy, TopologyService, TopoRequest, TopoResponse,
)

SVC_CFG = BATopoConfig(sa_iters=80, polish_iters=80)


def _nan_topology(n: int) -> Topology:
    edges = [(i, (i + 1) % n) for i in range(n)]
    g = np.full(len(edges), np.nan)
    return Topology(n, edges, g, name="nan-stub", meta={"connected": True})


# =========================================================================
# admission control
# =========================================================================

@pytest.mark.parametrize("kw,frag", [
    (dict(n=1, r=4), "n="),
    (dict(n=8, r=3), "never connect"),
    (dict(n=8, r=16, scenario="warp"), "unknown scenario"),
    (dict(n=8, r=16, scenario="node"), "requires node_bandwidths"),
    (dict(n=8, r=16, scenario="node",
          node_bandwidths=np.full(8, np.nan)), "finite and positive"),
    (dict(n=8, r=16, scenario="constraint"), "requires a ConstraintSet"),
    (dict(n=8, r=16, deadline_ms=-5.0), "deadline_ms"),
])
def test_malformed_specs_rejected_structurally(kw, frag):
    svc = TopologyService(cfg=SVC_CFG)
    out = svc.submit(TopoRequest(**kw))
    assert isinstance(out, TopoResponse)
    assert not out.ok and out.reason.startswith("malformed")
    assert frag in out.reason
    assert svc.stats["rejected_malformed"] == 1


def test_overload_burst_bounded_queue_rejection():
    svc = TopologyService(cfg=SVC_CFG, policy=ServicePolicy(max_queue=3))
    outs = [svc.submit(TopoRequest(n=8, r=16)) for _ in range(8)]
    admitted = [o for o in outs if isinstance(o, int)]
    rejected = [o for o in outs if isinstance(o, TopoResponse)]
    assert len(admitted) == 3 and len(rejected) == 5
    assert all("overloaded" in r.reason for r in rejected)
    assert svc.stats["rejected_overload"] == 5
    # the admitted ones still get valid answers (all collapse to one spec,
    # so the 2nd/3rd hit the cache the 1st one filled... within one drain
    # the bucket solves them together — either way: valid topologies).
    resps = svc.drain()
    assert len(resps) == 3
    for r in resps:
        assert r.ok and check_invariants(r.topology) is None


# =========================================================================
# cache
# =========================================================================

def test_cache_hit_bit_equal_to_fresh_optimize_topology():
    svc = TopologyService(cfg=SVC_CFG)
    miss = svc.request(12, 20)
    hit = svc.request(12, 20)
    assert miss.ok and not miss.cache_hit and miss.quality_tier == "full"
    assert hit.ok and hit.cache_hit and hit.quality_tier == "cache"
    ref = optimize_topology(12, 20, cfg=SVC_CFG)
    assert sorted(hit.topology.edges) == sorted(ref.edges)
    np.testing.assert_array_equal(np.asarray(hit.topology.W),
                                  np.asarray(ref.W))
    # and the hit is dramatically cheaper than the cold solve
    assert hit.latency_ms < miss.latency_ms / 10


def test_cache_capacity_lru_eviction():
    svc = TopologyService(cfg=SVC_CFG,
                          policy=ServicePolicy(cache_capacity=1))
    svc.request(8, 16)
    svc.request(10, 18)            # evicts the n=8 entry
    assert len(svc._cache) == 1
    again = svc.request(8, 16)
    assert not again.cache_hit     # was evicted → fresh solve


def test_drift_detector_invalidates_stale_entries():
    # Coarse quantization ⇒ both profiles share a cache key; the drift
    # check (25% threshold) must still invalidate the stale entry.
    pol = ServicePolicy(bw_quant=10.0, drift=DriftPolicy(bw_rel_threshold=0.25))
    svc = TopologyService(cfg=SVC_CFG, policy=pol)
    bw0 = np.full(8, 10.0)
    req0 = TopoRequest(n=8, r=16, scenario="node", node_bandwidths=bw0)
    key = svc._cache_key(req0)
    svc._cache_store(req0, key, _nan_topology(8))   # content irrelevant here
    drifted = TopoRequest(n=8, r=16, scenario="node",
                          node_bandwidths=bw0 * np.linspace(0.5, 1.0, 8))
    assert svc._cache_key(drifted) == key            # same canonical key
    assert svc._cache_lookup(drifted, key) is None   # …but drift-evicted
    assert svc.stats["invalidations"] == 1


def test_observe_telemetry_evicts_drifted_entries():
    pol = ServicePolicy(bw_quant=10.0)
    svc = TopologyService(cfg=SVC_CFG, policy=pol)
    bw0 = np.full(8, 10.0)
    req0 = TopoRequest(n=8, r=16, scenario="node", node_bandwidths=bw0)
    svc._cache_store(req0, svc._cache_key(req0), _nan_topology(8))
    assert svc.observe(bw0 * 1.05) == 0              # within threshold
    assert svc.observe(bw0 * 2.0) == 1               # drifted → evicted
    assert len(svc._cache) == 0


# =========================================================================
# bucketed misses
# =========================================================================

def test_bucketed_misses_match_one_shot_supports():
    svc = TopologyService(cfg=SVC_CFG)
    for r in (18, 24, 30):
        assert isinstance(svc.submit(TopoRequest(n=12, r=r)), int)
    resps = svc.drain()
    assert svc.stats["bucketed_solves"] == 1
    for r, resp in zip((18, 24, 30), resps):
        assert resp.ok and resp.quality_tier == "full"
        assert resp.profile.get("bucket_size") == 3
        ref = optimize_topology(12, r, cfg=SVC_CFG)
        assert sorted(resp.topology.edges) == sorted(ref.edges)


# =========================================================================
# deadline ladder + fault injection
# =========================================================================

def test_nan_solver_stub_degrades_to_valid_topology():
    """NaN-returning full-tier stub: release validation catches the garbage
    matrix and the ladder degrades — the caller still gets a valid W."""
    hooks = ServiceHooks(full=lambda req, prof: _nan_topology(int(req.n)))
    svc = TopologyService(cfg=SVC_CFG, hooks=hooks)
    resp = svc.request(8, 16)
    assert resp.ok and resp.degraded
    assert resp.quality_tier in ("warm", "sa_only", "classic")
    assert "full: invalid topology (finite violated)" in resp.reason
    assert check_invariants(resp.topology) is None


def test_raising_solver_stub_never_escapes():
    def explode(req, prof):
        raise SolveFailure(SolveOutcome.NON_FINITE, "injected")

    hooks = ServiceHooks(full=explode, warm=explode)
    svc = TopologyService(cfg=SVC_CFG, hooks=hooks)
    resp = svc.request(8, 16)
    assert resp.ok and resp.degraded
    assert "non_finite" in resp.reason
    assert check_invariants(resp.topology) is None


def test_deadline_expiry_mid_pipeline_degrades():
    """A slow full tier burns the whole deadline; the remaining optimizer
    rungs are skipped and the classic fallback answers — degraded tier,
    valid topology, deadline named in the reason trail."""
    def slow(req, prof):
        time.sleep(0.05)
        raise SolveFailure(SolveOutcome.NON_CONVERGENT, "slow stub")

    svc = TopologyService(cfg=SVC_CFG, hooks=ServiceHooks(full=slow))
    resp = svc.request(10, 16, deadline_ms=20.0)
    assert resp.ok and resp.quality_tier == "classic"
    assert "deadline expired" in resp.reason
    assert check_invariants(resp.topology) is None


def test_expired_deadline_goes_straight_to_classic():
    svc = TopologyService(cfg=SVC_CFG)
    req = TopoRequest(n=10, r=16, deadline_ms=1e-3)
    assert isinstance(svc.submit(req), int)
    time.sleep(0.01)                      # deadline passes while queued
    resp = svc.drain()[0]
    assert resp.ok and resp.quality_tier == "classic"
    assert check_invariants(resp.topology) is None


def test_fault_injection_harness_service_invariant():
    """The acceptance harness: a seeded mix of NaN solves, slow solves,
    raising solves, malformed specs and burst overload. Every request must
    get a valid topology or a structured rejection — zero exceptions."""
    rng = np.random.default_rng(0)

    def faulty_full(req, prof):
        roll = rng.integers(0, 3)
        if roll == 0:
            return _nan_topology(int(req.n))
        if roll == 1:
            raise SolveFailure(SolveOutcome.NON_FINITE, "injected NaN")
        raise RuntimeError("injected crash")

    def faulty_warm(req, prof):
        if rng.integers(0, 2) == 0:
            raise SolveFailure(SolveOutcome.NON_CONVERGENT, "injected")
        return None

    svc = TopologyService(
        cfg=SVC_CFG, policy=ServicePolicy(max_queue=8),
        hooks=ServiceHooks(full=faulty_full, warm=faulty_warm))

    responses: list[TopoResponse] = []
    for wave in range(3):
        for k in range(12):
            malformed = k % 5 == 4
            req = TopoRequest(
                n=1 if malformed else 8 + 2 * (k % 3),
                r=16 + 2 * (k % 4),
                deadline_ms=5.0 if k % 3 == 2 else None)
            out = svc.submit(req)
            if isinstance(out, TopoResponse):
                responses.append(out)
        responses.extend(svc.drain())

    assert len(responses) == 36
    n_ok = n_rej = 0
    for resp in responses:
        if resp.ok:
            n_ok += 1
            assert check_invariants(resp.topology) is None, resp.reason
            W = np.asarray(resp.topology.W)
            assert np.all(np.isfinite(W))
            np.testing.assert_allclose(W, W.T, atol=1e-8)
            np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-6)
        else:
            n_rej += 1
            assert resp.reason  # structured: always says why
    assert n_ok + n_rej == 36
    assert svc.stats["rejected_malformed"] > 0
    assert svc.stats["rejected_overload"] > 0
    assert n_ok > 0


def test_profile_dict_carries_phase_latency():
    svc = TopologyService(cfg=SVC_CFG)
    resp = svc.request(10, 16)
    assert resp.ok and resp.quality_tier == "full"
    for key in ("queue_s", "solve_s", "warm_s", "admm_s", "round_s",
                "polish_s", "eval_s"):
        assert key in resp.profile, key


# =========================================================================
# EMA seeding from tracked bench rows (DESIGN.md §17)
# =========================================================================

def test_ema_seeded_from_tracked_pipeline_rows():
    rows = [
        {"bench": "pipeline", "n": 64, "pipeline": "device", "restarts": 4,
         "total_s": 8.0, "warm_s": 0.6, "admm_s": 5.8, "round_s": 0.004,
         "polish_s": 1.6, "eval_s": 0.004},
        {"bench": "pipeline", "n": 64, "pipeline": "host", "total_s": 30.0},
        {"bench": "admm", "n": 16, "ms_per_iter": 1.0},   # not a pipeline row
    ]
    svc = TopologyService(cfg=SVC_CFG, bench_rows=rows)
    assert svc.stats["ema_seeded"] == 1
    assert svc._ema_ms[("full", 64)] == pytest.approx(8000.0)
    # the per-phase seed profile is per restart (stage-invocation priors)
    prof = svc._seed_profiles[64]
    assert prof.phases["warm"] == pytest.approx(0.15)
    assert prof.phases["admm"] == pytest.approx(1.45)


def test_ema_seeding_opt_out_and_live_updates_win():
    rows = [{"bench": "pipeline", "n": 16, "pipeline": "device",
             "restarts": 1, "total_s": 4.0, "warm_s": 1.0}]
    svc = TopologyService(cfg=SVC_CFG,
                          policy=ServicePolicy(ema_seed=False),
                          bench_rows=rows)
    assert svc.stats["ema_seeded"] == 0 and not svc._ema_ms
    # seeded prior is a default, not a pin: a real solve replaces it
    svc2 = TopologyService(cfg=SVC_CFG, bench_rows=rows)
    assert svc2._ema_ms[("full", 16)] == pytest.approx(4000.0)
    resp = svc2.request(16, 32)
    assert resp.ok
    assert svc2._ema_ms[("full", 16)] != pytest.approx(4000.0)
