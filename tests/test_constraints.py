"""Heterogeneous constraint builders (M, e) — §IV-B scenarios."""
import numpy as np

from repro.core.constraints import bcube_constraints, intra_server_constraints, node_level_constraints, pod_boundary_constraints
from repro.core.graph import all_edges, edge_index
from repro.core.topologies import exponential


def test_node_level_matrix_is_abs_incidence():
    """Eq. (16): M = abs(A)."""
    from repro.core.graph import incidence_matrix

    n = 6
    cs = node_level_constraints(n, np.full(n, 3), np.full(n, 9.76))
    A = incidence_matrix(n)
    np.testing.assert_array_equal(cs.M, np.abs(A).astype(np.int64))
    assert cs.equality


def test_intra_server_exponential_maps_10_edges_to_sys():
    """§VI-A3: the n=8 exponential graph maps exactly 10 edges onto the SYS
    link → min edge bandwidth 9.76/10 = 0.976 GB/s."""
    cs = intra_server_constraints()
    t = exponential(8)
    eidx = edge_index(8)
    sel = np.zeros(len(all_edges(8)), dtype=bool)
    for e in t.edges:
        sel[eidx[e]] = True
    usage = cs.usage(sel)
    assert usage[6] == 10  # SYS row
    bw = cs.edge_bandwidth(sel)
    assert abs(min(bw[sel]) - 9.76 / 10) < 1e-9


def test_intra_server_capacities_match_class_sizes():
    """e = (1,1,1,1,4,4,16): each class capacity equals #possible edges."""
    cs = intra_server_constraints()
    class_sizes = cs.M.sum(axis=1)
    np.testing.assert_array_equal(class_sizes, [1, 1, 1, 1, 4, 4, 16])
    assert not cs.equality


def test_bcube_admissibility():
    """BCube(4,2): only one-digit-different pairs are admissible; each
    admissible edge consumes exactly two ports at one layer."""
    cs = bcube_constraints(4, 2)
    edges = all_edges(16)
    n_adm = int(cs.edge_ok.sum())
    # per layer: 4 groups of C(4,2)=6 edges → 24; two layers → 48
    assert n_adm == 48
    for l, (i, j) in enumerate(edges):
        col = cs.M[:, l]
        if cs.edge_ok[l]:
            assert col.sum() == 2
        else:
            assert col.sum() == 0
    assert np.all(cs.e_cap == 3)


def test_bcube_full_selection_feasible():
    """Selecting ALL admissible edges saturates every port at exactly p−1."""
    cs = bcube_constraints(4, 2)
    z = cs.edge_ok.astype(np.int64)
    usage = cs.usage(z)
    np.testing.assert_array_equal(usage, np.full(32, 3))
    assert cs.feasible(z)


def test_pod_boundary():
    cs = pod_boundary_constraints(8, pods=2, dci_cap_total=3)
    edges = all_edges(8)
    # cross-pod edges hit the aggregate DCI row
    cross = [l for l, (i, j) in enumerate(edges) if (i < 4) != (j < 4)]
    assert all(cs.M[8, l] == 1 for l in cross)
    z = np.zeros(len(edges), dtype=np.int64)
    for l in cross[:4]:
        z[l] = 1
    assert not cs.feasible(z)  # 4 > dci_cap_total=3
