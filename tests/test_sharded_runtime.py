"""Multi-device runtime invariants, exercised in a subprocess with 8 host
devices (the main pytest process must keep the default single device — the
brief forbids setting XLA_FLAGS globally)."""
import os
import subprocess
import sys

import pytest

import jax

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import make_baseline, optimize_topology, BATopoConfig
from repro.core.admm import ADMMConfig
from repro.core.graph import weight_matrix_from_weights
from repro.dsgd import schedule_from_topology
from repro.dsgd.gossip import gossip_shard, gossip_sim
from repro.roofline import collective_bytes_from_hlo

mesh = jax.make_mesh((4, 2), ("data", "model"))
n = 4

# --- 1. ppermute gossip == dense W matmul on a real multi-device mesh ------
topo = optimize_topology(n, 5, "homo",
                         cfg=BATopoConfig(sa_iters=100, admm=ADMMConfig(max_iters=30)))
sched = schedule_from_topology(topo)
W = weight_matrix_from_weights(n, topo.edges, topo.g)

x = jax.random.normal(jax.random.PRNGKey(0), (n, 6, 64))

def worker(xs):
    return gossip_shard(xs, sched, "data")

g = jax.shard_map(worker, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                  axis_names={"data"}, check_vma=False)
with jax.set_mesh(mesh):
    out = jax.jit(g)(x)
expect = gossip_sim(x, jnp.asarray(W, jnp.float32))
np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)
print("GOSSIP_EQUIV_OK")

# --- 2. HLO parser trip-count correction vs unrolled ground truth ----------
def make(fn_len, unroll):
    def f(xs):
        def body(c, _):
            s = jax.lax.psum(c, "data")
            return jnp.tanh(s @ w0), None
        c, _ = jax.lax.scan(body, xs[0], None, length=fn_len, unroll=unroll)
        return c
    return f

w0 = jnp.ones((64, 64))
xs = jax.device_put(jnp.ones((4, 64, 64)),
                    NamedSharding(mesh, P("data", None, None)))
L = 6
with jax.set_mesh(mesh):
    txts = {}
    for tag, unroll in [("scan", 1), ("unrolled", L)]:
        g2 = jax.shard_map(make(L, unroll), mesh=mesh, in_specs=P("data"),
                           out_specs=P(None), axis_names={"data"},
                           check_vma=False)
        txts[tag] = jax.jit(g2).lower(xs).compile().as_text()
scan_bytes = collective_bytes_from_hlo(txts["scan"])["total"]
unrolled_bytes = collective_bytes_from_hlo(txts["unrolled"])["total"]
assert unrolled_bytes > 0
ratio = scan_bytes / unrolled_bytes
assert 0.8 < ratio < 1.25, (scan_bytes, unrolled_bytes)
print("PARSER_TRIPCOUNT_OK", scan_bytes, unrolled_bytes)

# --- 3. sharded DSGD train step lowers + matches the sim oracle ------------
from repro.configs import get_arch, reduced_for_smoke
from repro.dsgd import (init_dsgd_state, dsgd_train_step, make_sharded_train_step)
from repro.optim import sgd_momentum
from repro.data import DataConfig, synthetic_lm_batch

cfg = reduced_for_smoke(get_arch("qwen1.5-0.5b"))
opt_init, opt_update = sgd_momentum(0.05)
state = init_dsgd_state(jax.random.PRNGKey(0), cfg, n, opt_init)
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2)
per = [synthetic_lm_batch(dc, 0, node=i) for i in range(n)]
batch = {k: jnp.stack([b[k] for b in per]) for k in per[0]}

sim_step = dsgd_train_step(cfg, topo, opt_update)
sharded_step = make_sharded_train_step(cfg, sched, opt_update, mesh,
                                       gossip_axes=("data",))
with jax.set_mesh(mesh):
    s_sharded, m_sharded = jax.jit(sharded_step)(state, batch)
s_sim, m_sim = sim_step(state, batch)
np.testing.assert_allclose(float(m_sharded["loss"]), float(m_sim["loss"]),
                           rtol=1e-4)
for a, b in zip(jax.tree.leaves(s_sharded.params), jax.tree.leaves(s_sim.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)
print("SHARDED_STEP_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(
    not (hasattr(jax, "set_mesh") and hasattr(jax, "shard_map")),
    reason="requires the jax>=0.6 top-level set_mesh/shard_map APIs "
           "(capability check — the subprocess script uses both)")
def test_multi_device_runtime():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for marker in ("GOSSIP_EQUIV_OK", "PARSER_TRIPCOUNT_OK", "SHARDED_STEP_OK"):
        assert marker in res.stdout, res.stdout + "\n" + res.stderr
