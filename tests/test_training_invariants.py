"""System-level DSGD invariants (hypothesis property tests on the trainer)."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced_for_smoke
from repro.core.graph import weight_matrix_from_weights
from repro.data import DataConfig, synthetic_lm_batch
from repro.dsgd import dsgd_train_step, gossip_sim_tree, init_dsgd_state
from repro.optim import sgd_momentum
from tests.test_dsgd import _random_topology


@settings(max_examples=8, deadline=None)
@given(n=st.integers(3, 10), extra=st.integers(0, 8), seed=st.integers(0, 500))
def test_gossip_preserves_parameter_mean(n, extra, seed):
    """x ← W x with doubly-stochastic W preserves the worker mean exactly —
    THE invariant that makes DSGD track centralized SGD."""
    topo = _random_topology(n, extra, seed)
    W = jnp.asarray(weight_matrix_from_weights(n, topo.edges, topo.g), jnp.float32)
    tree = {"a": jax.random.normal(jax.random.PRNGKey(seed), (n, 13, 7)),
            "b": jax.random.normal(jax.random.PRNGKey(seed + 1), (n, 130))}
    mixed = gossip_sim_tree(tree, W)
    for k in tree:
        np.testing.assert_allclose(np.asarray(mixed[k].mean(0)),
                                   np.asarray(tree[k].mean(0)), atol=1e-5)


def test_train_step_mean_equals_mean_of_local_updates():
    """After one DSGD step, mean(params) == mean(locally-updated params):
    gossip redistributes but never invents or destroys mass."""
    cfg = reduced_for_smoke(get_arch("smollm-135m"))
    n = 4
    topo = _random_topology(n, 3, 0)
    opt_init, opt_update = sgd_momentum(0.05)
    state = init_dsgd_state(jax.random.PRNGKey(0), cfg, n, opt_init)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2)
    per = [synthetic_lm_batch(dc, 0, node=i) for i in range(n)]
    batch = {k: jnp.stack([b[k] for b in per]) for k in per[0]}

    new_state, _ = dsgd_train_step(cfg, topo, opt_update)(state, batch)

    # recompute the pre-gossip local updates by hand
    from repro.dsgd.trainer import _loss_fn
    from repro.optim import apply_updates
    loss_fn = _loss_fn(cfg)
    _, grads = jax.vmap(jax.value_and_grad(loss_fn))(state.params, batch)
    updates, _ = jax.vmap(opt_update)(grads, state.opt, state.params)
    local = jax.vmap(apply_updates)(state.params, updates)
    for a, b in zip(jax.tree.leaves(new_state.params), jax.tree.leaves(local)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32).mean(0), np.asarray(b, np.float32).mean(0),
            atol=3e-5)


def test_identical_data_keeps_workers_identical():
    """With identical batches everywhere, DSGD == SGD: consensus error 0."""
    cfg = reduced_for_smoke(get_arch("qwen1.5-0.5b"))
    n = 4
    topo = _random_topology(n, 2, 1)
    opt_init, opt_update = sgd_momentum(0.05)
    state = init_dsgd_state(jax.random.PRNGKey(0), cfg, n, opt_init)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2)
    b0 = synthetic_lm_batch(dc, 0, node=0)
    batch = {k: jnp.stack([b0[k]] * n) for k in b0}
    step = dsgd_train_step(cfg, topo, opt_update)
    for _ in range(3):
        state, m = step(state, batch)
    assert float(m["consensus_err"]) < 1e-4
