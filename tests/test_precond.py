"""Solver performance stack (DESIGN.md §9): Jacobi diagonal correctness,
inexact-CG iteration-count regression, PSD-backend parity, precision modes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine as E
from repro.core.admm import ADMMConfig, HeterogeneousADMM, HomogeneousADMM
from repro.core.constraints import bcube_constraints, node_level_constraints, pod_boundary_constraints
from repro.core.graph import all_edges
from repro.core.linalg import pcg_solve, schur_cg_solve


def _materialized_diag(spec):
    """diag(A Aᵀ) by applying Aᵀ to every constraint-space unit vector."""
    ct = E.b_rhs(spec)
    leaves, tdef = jax.tree.flatten(jax.tree.map(jnp.zeros_like, ct))
    out = []
    for li, leaf in enumerate(leaves):
        flat = jnp.zeros(leaf.size)
        vals = []
        for k in range(leaf.size):
            ls = [jnp.zeros_like(x) for x in leaves]
            ls[li] = flat.at[k].set(1.0).reshape(leaf.shape)
            prim = E.AT_op(spec, jax.tree.unflatten(tdef, ls))
            vals.append(sum(float(jnp.sum(p.astype(jnp.float64) ** 2))
                            for p in jax.tree.leaves(prim)))
        out.append(np.asarray(vals).reshape(leaf.shape))
    return out


def test_jacobi_diag_homo():
    """Analytic diag(A Aᵀ) == materialized diagonal, homogeneous n=6."""
    spec = E.make_homo_spec(6, 8, ADMMConfig(precond="jacobi"))
    want = _materialized_diag(spec)
    assert len(want) == len(spec.jd) == 3
    for a, b in zip(want, spec.jd):
        np.testing.assert_allclose(a, np.asarray(b), atol=1e-12)


@pytest.mark.parametrize("equality", [True, False])
def test_jacobi_diag_hetero(equality):
    """Analytic diag == materialized diagonal for the heterogeneous operator
    with capacity + coupling rows, both M z = e and M z + s = e forms."""
    n = 6
    if equality:
        cs = node_level_constraints(n, np.full(n, 3), np.full(n, 9.76))
    else:
        cs = pod_boundary_constraints(n, pods=2)
    spec = E.make_hetero_spec(n, 8, np.asarray(cs.M, float),
                              np.asarray(cs.e_cap, float),
                              ADMMConfig(precond="jacobi"), equality=equality)
    want = _materialized_diag(spec)
    assert len(want) == len(spec.jd) == 5
    for a, b in zip(want, spec.jd):
        np.testing.assert_allclose(a, np.asarray(b), atol=1e-12)


def test_pcg_matches_reference_cg():
    """The counting PCG solves the X-step to the same solution as the PR-1
    ``jax.scipy`` CG wrapper (exact tolerance, warm start)."""
    from functools import partial

    n, r = 8, 12
    spec = E.make_homo_spec(n, r, ADMMConfig(precond="jacobi"))
    rng = np.random.default_rng(0)
    g0 = 0.2 * rng.random(spec.m)
    st = E.init_state(spec, jnp.asarray(g0), 0.4)
    U = tuple(jax.tree.map(lambda x, d: x + d / spec.rho, st.X, st.D))
    Y = E._project_blocks(spec, U)
    V = E._xstep_target(spec, Y, st.D)
    A, AT = partial(E.A_op, spec), partial(E.AT_op, spec)
    b = E.b_rhs(spec)
    X_ref, _ = schur_cg_solve(A, AT, V, b, st.lam, tol=1e-12, maxiter=3000)
    for jd in (None, spec.jd):  # plain and Jacobi-preconditioned
        X, _, iters = pcg_solve(A, AT, V, b, st.lam, jd=jd, tol=1e-12,
                                maxiter=3000)
        assert int(iters) > 0
        for a, bb in zip(jax.tree.leaves(X_ref), jax.tree.leaves(X)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                       atol=1e-8)


def test_cg_iteration_count_regression():
    """Preconditioned+inexact CG spends ≤ 0.5× the seed configuration's
    cumulative CG iterations on the n=16 BCube(4,2) test instance.

    The seed configuration is the PR-1 default: unpreconditioned CG solved
    to the fixed 1e-11 tolerance every ADMM iteration. The fast stack ties
    the tolerance to the primal residual (loose early, tight late); the
    Jacobi preconditioner rides along per the issue formula (on its own it
    *costs* iterations here — DESIGN.md §9 — the savings come from the
    inexactness schedule).
    """
    cs = bcube_constraints(4, 2)
    n, r = 16, 32
    m = len(all_edges(n))
    rng = np.random.default_rng(0)
    g0 = np.zeros(m)
    idx = np.nonzero(np.asarray(cs.edge_ok))[0]
    g0[rng.choice(idx, size=r, replace=False)] = 1.0 / r
    z0 = (g0 > 0).astype(float)

    def solve(**kw):
        cfg = ADMMConfig(max_iters=60, **kw)
        sol = HeterogeneousADMM(n, r, np.asarray(cs.M, float),
                                np.asarray(cs.e_cap, float), cfg,
                                equality=cs.equality,
                                edge_ok=np.asarray(cs.edge_ok))
        return sol.solve(g0=g0, z0=z0, lam0=0.3)

    seed = solve(precond="none")
    fast = solve(precond="jacobi", cg_inexact=True)
    assert seed.cg_iters > 0 and fast.cg_iters > 0
    ratio = fast.cg_iters / seed.cg_iters
    assert ratio <= 0.5, f"cumulative CG ratio {ratio:.3f} (want ≤ 0.5)"
    # inexactness must not wreck progress: same residual order of magnitude
    assert fast.residual <= 10.0 * seed.residual


def test_proj_psd_ns_parity():
    """Newton–Schulz projection deviates from the eigh projection by a
    bounded amount and lands (numerically) in the right cone."""
    rng = np.random.default_rng(0)
    for n in (8, 24):
        M = rng.normal(size=(n, n))
        M = (M + M.T) / 2
        scale = np.abs(M).max()
        for sign in (+1.0, -1.0):
            P_eigh = np.asarray(E.proj_psd(jnp.asarray(M), sign))
            P_ns = np.asarray(E.proj_psd_ns(jnp.asarray(M), sign, iters=30))
            assert np.abs(P_eigh - P_ns).max() <= 1e-4 * scale
            ev = np.linalg.eigvalsh(P_ns)
            if sign > 0:
                assert ev.min() >= -1e-4 * scale
            else:
                assert ev.max() <= 1e-4 * scale


def test_psd_backends_runtime_selectable():
    """Both PSD backends run through the full solver and agree on the
    converged objective from a structured warm start."""
    from repro.core.anneal import greedy_degree_graph
    from repro.core.graph import edge_index
    from repro.core.weights import metropolis_weights

    n, r = 8, 12
    rng = np.random.default_rng(0)
    edges = greedy_degree_graph(n, np.full(n, 3), rng)
    eidx = edge_index(n)
    g0 = np.zeros(len(all_edges(n)))
    for k, e in enumerate(edges):
        g0[eidx[e]] = metropolis_weights(n, edges)[k]
    res_e = HomogeneousADMM(n, r, ADMMConfig(max_iters=400)).solve(g0=g0, lam0=0.4)
    res_n = HomogeneousADMM(
        n, r, ADMMConfig(max_iters=400, psd_backend="newton_schulz")
    ).solve(g0=g0, lam0=0.4)
    assert res_n.lam_tilde == pytest.approx(res_e.lam_tilde, abs=1e-3)


def test_fp32_mode():
    """dtype='float32' keeps the iterate in fp32 (no silent upcast through
    the scan loop) while residuals/convergence stay fp64, and reaches the
    same objective as fp64 within fp32 slack."""
    from repro.core.anneal import greedy_degree_graph
    from repro.core.graph import edge_index
    from repro.core.weights import metropolis_weights

    n, r = 8, 12
    rng = np.random.default_rng(0)
    edges = greedy_degree_graph(n, np.full(n, 3), rng)
    eidx = edge_index(n)
    g0 = np.zeros(len(all_edges(n)))
    for k, e in enumerate(edges):
        g0[eidx[e]] = metropolis_weights(n, edges)[k]

    spec32 = E.make_homo_spec(n, r, ADMMConfig(dtype="float32"))
    st = E.init_state(spec32, jnp.asarray(g0), 0.4)
    assert all(x.dtype == jnp.float32 for x in jax.tree.leaves(st.X))
    st2, res = E.step(spec32, st)
    assert all(x.dtype == jnp.float32 for x in jax.tree.leaves(st2.X))
    assert res.dtype == jnp.float64

    res64 = HomogeneousADMM(n, r, ADMMConfig(max_iters=400)).solve(g0=g0, lam0=0.4)
    res32 = HomogeneousADMM(
        n, r, ADMMConfig(max_iters=400, dtype="float32", cg_inexact=True)
    ).solve(g0=g0, lam0=0.4)
    assert res32.lam_tilde == pytest.approx(res64.lam_tilde, abs=1e-3)


def test_inexact_tolerance_schedule():
    """The adaptive tolerance starts at the cap (res = ∞), tightens with the
    residual, and never crosses the floor."""
    spec = E.make_homo_spec(6, 8, ADMMConfig(cg_inexact=True))
    cap = max(E.INEXACT_CAP, spec.cg_tol)
    assert float(E._cg_tolerance(spec, jnp.asarray(jnp.inf))) == cap
    mid = float(E._cg_tolerance(spec, jnp.asarray(1e-4)))
    assert spec.cg_tol < mid < cap
    assert float(E._cg_tolerance(spec, jnp.asarray(0.0))) == spec.cg_tol
    # exact mode ignores the schedule entirely
    spec_exact = E.make_homo_spec(6, 8, ADMMConfig())
    assert E._cg_tolerance(spec_exact, jnp.asarray(jnp.inf)) == spec_exact.cg_tol
    # fp32 floors the request at what the dtype resolves
    spec32 = E.make_homo_spec(6, 8, ADMMConfig(dtype="float32"))
    assert E._cg_tolerance(spec32, jnp.asarray(jnp.inf)) == E.FP32_TOL_FLOOR


def test_ilu_requires_fp64():
    with pytest.raises(ValueError, match="float64"):
        HomogeneousADMM(6, 8, ADMMConfig(solver="kkt_bicgstab_ilu",
                                         dtype="float32")).solve()
