"""Device SA warm start vs the host parity oracle (DESIGN.md §10).

The device SA does not replicate the host RNG stream — trajectories
differ — so parity is on *invariants* (degree preservation, feasibility,
connectivity) and on solution quality (ASPL within tolerance), while the
matmul-BFS ASPL itself must equal ``graph.aspl`` exactly.
"""
import numpy as np
import pytest

from repro.core.anneal import anneal_topology, greedy_degree_graph
from repro.core.api import _greedy_constraint_graph
from repro.core.constraints import bcube_constraints, intra_server_constraints
from repro.core.graph import all_edges, aspl, degrees, edge_index, is_connected
from repro.core.warmstart import anneal_topology_batched, aspl_matmul


def _random_adjacency(n, p, rng):
    up = rng.random((n, n)) < p
    adj = np.triu(up, 1)
    adj = adj | adj.T
    return adj


def _edges_of(adj):
    return [tuple(e) for e in np.argwhere(np.triu(adj, 1)).tolist()]


def test_aspl_matmul_matches_graph_aspl_exactly():
    rng = np.random.default_rng(0)
    for n, p in ((5, 0.5), (9, 0.3), (16, 0.2), (33, 0.15), (64, 0.08)):
        for _ in range(3):
            adj = _random_adjacency(n, p, rng)
            edges = _edges_of(adj)
            assert aspl_matmul(adj) == aspl(n, edges)  # == : bit-identical


def test_aspl_matmul_disconnected_is_inf():
    adj = np.zeros((8, 8), dtype=bool)
    adj[0, 1] = adj[1, 0] = True
    adj[2, 3] = adj[3, 2] = True
    assert np.isinf(aspl_matmul(adj))
    assert aspl(8, [(0, 1), (2, 3)]) == float("inf")


def test_aspl_matmul_kernel_path_matches():
    rng = np.random.default_rng(1)
    adj = _random_adjacency(24, 0.2, rng)
    assert aspl_matmul(adj, use_kernel=True) == aspl_matmul(adj)


def test_device_sa_invariants_and_quality_homo():
    n, iters = 16, 500
    inits = [greedy_degree_graph(n, np.full(n, 4), np.random.default_rng(k))
             for k in range(3)]
    outs = anneal_topology_batched(n, inits, iters=iters, seeds=[1, 2, 3])
    hosts = [anneal_topology(n, e0, iters=iters, seed=k + 1)
             for k, e0 in enumerate(inits)]
    for e0, dev, host in zip(inits, outs, hosts):
        assert is_connected(n, dev)
        # 2-swaps preserve the degree sequence exactly
        assert (degrees(n, dev) == degrees(n, e0)).all()
        # SA minimizes ASPL: never worse than the start, and within
        # tolerance of the host oracle on the same instance
        assert aspl(n, dev) <= aspl(n, e0) + 1e-12
        assert abs(aspl(n, dev) - aspl(n, host)) < 0.25


def test_device_sa_respects_inequality_constraints():
    cs = intra_server_constraints(8)
    inits, seeds = [], []
    for k in range(4):  # collect a same-edge-count batch
        e0 = _greedy_constraint_graph(8, 12, cs, np.random.default_rng(k))
        if inits and len(e0) != len(inits[0]):
            continue
        inits.append(e0)
        seeds.append(10 + k)
    outs = anneal_topology_batched(8, inits[:2], cs, iters=300,
                                   seeds=seeds[:2])
    eidx = edge_index(8)
    m = len(all_edges(8))
    for dev in outs:
        z = np.zeros(m, dtype=bool)
        for e in dev:
            z[eidx[e]] = True
        assert cs.feasible(z)
        assert is_connected(8, dev)


def test_device_sa_respects_edge_admissibility():
    cs = bcube_constraints(4, 2)  # n = 16, only one-hop pairs admissible
    n = 16
    inits = [_greedy_constraint_graph(n, 24, cs, np.random.default_rng(k + 7))
             for k in range(2)]
    if len(inits[0]) != len(inits[1]):
        inits = [inits[0]]
    outs = anneal_topology_batched(n, inits, cs, iters=250,
                                   seeds=list(range(len(inits))))
    eidx = edge_index(n)
    m = len(all_edges(n))
    for dev in outs:
        z = np.zeros(m, dtype=bool)
        for e in dev:
            z[eidx[e]] = True
        assert not z[~np.asarray(cs.edge_ok)].any()
        assert cs.feasible(z)


def test_device_sa_tiny_edge_sets_passthrough():
    # fewer than 2 edges: no 2-swap exists; host loop bails, device mirrors
    out = anneal_topology_batched(3, [[(0, 1)]], iters=50, seeds=[0])
    assert out == [[(0, 1)]]


def test_device_sa_batch_requires_equal_edge_counts():
    with pytest.raises(AssertionError):
        anneal_topology_batched(5, [[(0, 1), (1, 2)], [(0, 1)]], iters=10)
