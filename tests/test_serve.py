"""Serving engine behaviors: EOS stop, determinism, ring-cache decode
equivalence, functional serve step."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced_for_smoke
from repro.models import transformer
from repro.serve import DecodeState, ServeConfig, ServingEngine, make_functional_serve_step


@pytest.fixture(scope="module")
def dense_setup():
    cfg = reduced_for_smoke(get_arch("qwen1.5-0.5b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_greedy_generation_deterministic(dense_setup):
    cfg, params = dense_setup
    scfg = ServeConfig(batch_size=2, cache_len=48, max_new_tokens=8)
    prompts = np.random.default_rng(0).integers(1, cfg.vocab_size, (2, 8)).astype(np.int32)
    out1 = ServingEngine(cfg, params, scfg, eos_id=-1).generate(prompts)
    out2 = ServingEngine(cfg, params, scfg, eos_id=-1).generate(prompts)
    np.testing.assert_array_equal(out1, out2)


def test_eos_padding(dense_setup):
    """After a request emits EOS, all its further tokens are EOS."""
    cfg, params = dense_setup
    # pick the argmax token of the first step as the EOS id → stops at once
    scfg = ServeConfig(batch_size=2, cache_len=48, max_new_tokens=6)
    prompts = np.random.default_rng(0).integers(1, cfg.vocab_size, (2, 8)).astype(np.int32)
    probe = ServingEngine(cfg, params, scfg, eos_id=-1).generate(prompts)
    eos = int(probe[0, 1])
    out = ServingEngine(cfg, params, scfg, eos_id=eos).generate(prompts)
    row = out[0].tolist()
    if eos in row:
        k = row.index(eos)
        assert all(t == eos for t in row[k:])


def test_decode_matches_prefill_continuation(dense_setup):
    """decode_step over the prompt reproduces prefill's final logits."""
    cfg, params = dense_setup
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 12)), jnp.int32)
    logits_p, _ = transformer.prefill(params, cfg, {"tokens": toks}, cache_cap=16)
    caches = transformer.init_caches(cfg, 1, 16)
    logits_d = None
    for t in range(12):
        logits_d, caches = transformer.decode_step(
            params, cfg, toks[:, t:t + 1], caches, jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_p)[:, -1],
                               np.asarray(logits_d)[:, -1], atol=2e-3, rtol=2e-3)


def test_functional_serve_step_lowers_and_runs(dense_setup):
    cfg, params = dense_setup
    scfg = ServeConfig(batch_size=3, cache_len=32)
    step = make_functional_serve_step(cfg, scfg, eos_id=-1)
    caches = transformer.init_caches(cfg, 3, 32)
    state = DecodeState(tokens=jnp.ones((3, 1), jnp.int32), caches=caches,
                        pos=jnp.asarray(5, jnp.int32),
                        rng=jnp.zeros((2,), jnp.uint32),
                        done=jnp.zeros((3,), bool))
    out = jax.jit(step)(params, state)
    assert out.tokens.shape == (3, 1) and int(out.pos) == 6
    assert np.isfinite(np.asarray(out.tokens)).all()


def test_ring_cache_long_context_ssm():
    """SSM decode with long_context: state carries, no KV growth."""
    cfg = reduced_for_smoke(get_arch("mamba2-780m"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    caches = transformer.init_caches(cfg, 2, 8)
    tok = jnp.ones((2, 1), jnp.int32)
    for t in range(20):  # run far past any cache capacity
        logits, caches = transformer.decode_step(
            params, cfg, tok, caches, jnp.asarray(t, jnp.int32), long_context=True)
    assert np.isfinite(np.asarray(logits)).all()
