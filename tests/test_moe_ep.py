"""Expert-parallel MoE (shard_map + all_to_all) vs the pjit oracle.
Runs in a multi-device subprocess (main pytest keeps 1 device)."""
import os
import subprocess
import sys

import pytest

import jax

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.moe import init_moe, moe_forward
from repro.models.moe_ep import moe_forward_expert_parallel

mesh = jax.make_mesh((2, 4), ("data", "model"))

for E, k, cf in [(8, 2, 8.0), (4, 1, 8.0), (16, 4, 8.0)]:
    d, F = 32, 64
    p = init_moe(jax.random.PRNGKey(E), d, F, E, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(E + 1), (4, 16, d))
    ref, aux_ref = moe_forward(p, x, top_k=k, capacity_factor=cf)
    with jax.set_mesh(mesh):
        xs = jax.device_put(x, NamedSharding(mesh, P("data")))
        out, aux = jax.jit(lambda p, x: moe_forward_expert_parallel(
            p, x, top_k=k, capacity_factor=cf))(p, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-4)
    print(f"EP_OK E={E} k={k}")

# gradients flow through the shard_map dispatch
p = init_moe(jax.random.PRNGKey(0), 32, 64, 8, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
def loss_ep(p, x):
    out, aux = moe_forward_expert_parallel(p, x, top_k=2, capacity_factor=8.0)
    return jnp.sum(out ** 2) + 0.01 * aux
def loss_ref(p, x):
    out, aux = moe_forward(p, x, top_k=2, capacity_factor=8.0)
    return jnp.sum(out ** 2) + 0.01 * aux
with jax.set_mesh(mesh):
    g_ep = jax.jit(jax.grad(loss_ep))(p, x)
g_ref = jax.grad(loss_ref)(p, x)
for a, b in zip(jax.tree.leaves(g_ep), jax.tree.leaves(g_ref)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3, rtol=2e-3)
print("EP_GRAD_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(jax, "set_mesh"),
    reason="requires the jax>=0.6 top-level set_mesh API "
           "(capability check — the subprocess script enters the mesh with it)")
def test_expert_parallel_moe_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert res.stdout.count("EP_OK") == 3 and "EP_GRAD_OK" in res.stdout


def test_supports_expert_parallel():
    from repro.models.moe_ep import supports_expert_parallel
    assert supports_expert_parallel(32, 16)      # granite
    assert not supports_expert_parallel(8, 16)   # mixtral: needs virtual experts
