"""Anytime topology pipeline: request/result API, incumbent semantics,
parity oracle against the phase-barriered pipeline (DESIGN.md §17)."""
import numpy as np
import pytest

from repro.core import BATopoConfig
from repro.core.anytime import (
    AnytimeSolver,
    PhaseProfile,
    TopologyRequest,
    solve_topologies,
    solve_topology,
    validate_request,
)
from repro.core.api import optimize_topology
from repro.core.constraints import bcube_constraints, intra_server_constraints
from repro.core.guard import check_invariants, validate_topology
from repro.core.reopt import reoptimize_topology
from repro.core.warmstart import anneal_topology_batched, anneal_topology_stream

FAST = BATopoConfig(sa_iters=120, polish_iters=100, restarts=2)

NODE_BW_16 = np.array([9.76] * 8 + [3.25] * 8)


def _support(topo):
    return sorted(tuple(sorted(e)) for e in topo.edges)


# =========================================================================
# the parity oracle: budget_ms=None replays the barrier pipeline
# =========================================================================

@pytest.mark.parametrize("kw", [
    dict(n=16, r=32, scenario="homo"),
    dict(n=16, r=32, scenario="node", node_bandwidths=NODE_BW_16),
    dict(n=8, r=12, scenario="constraint", cs=intra_server_constraints(8)),
    dict(n=16, r=48, scenario="constraint", cs=bcube_constraints(p=4, k=2)),
], ids=["homo", "node", "intra", "bcube"])
def test_unbudgeted_parity_with_barrier(kw):
    """Unbudgeted anytime result is support-equal to the pre-refactor
    ``optimize_topology`` on every paper scenario, with r_asym drift ≤ 1e-3
    (the ISSUE-10 acceptance band; in practice the replay is bit-exact)."""
    with pytest.deprecated_call():
        legacy = optimize_topology(kw["n"], kw["r"], kw["scenario"],
                                   cs=kw.get("cs"),
                                   node_bandwidths=kw.get("node_bandwidths"),
                                   cfg=FAST)
    res = solve_topology(TopologyRequest(**kw), cfg=FAST)
    assert res.complete and res.quality_tier == "full"
    assert _support(res.topology) == _support(legacy)
    assert abs(res.r_asym - float(legacy.meta["r_asym"])) <= 1e-3
    assert res.topology.meta.get("selected_from") == \
        legacy.meta.get("selected_from")


def test_barrier_engine_matches_legacy_exactly():
    with pytest.deprecated_call():
        legacy = optimize_topology(12, 24, "homo", cfg=FAST)
    prof: dict = {}
    res = solve_topology(TopologyRequest(n=12, r=24), cfg=FAST,
                         profile=prof, engine="barrier")
    assert _support(res.topology) == _support(legacy)
    assert res.quality_tier == "full" and res.complete
    assert set(prof) >= {"warm_s", "admm_s", "polish_s", "eval_s"}


def test_solve_topologies_matches_sweep_grouping():
    """The batch front end groups sweepable homo requests through the
    legacy sweep engine (same amortized batching, same results) and solves
    hetero requests individually, returning results in input order."""
    from repro.core.api import sweep_topologies

    reqs = [TopologyRequest(n=12, r=24),
            TopologyRequest(n=8, r=12, scenario="constraint",
                            cs=intra_server_constraints(8)),
            TopologyRequest(n=12, r=18)]
    out = solve_topologies(reqs, cfg=FAST)
    assert len(out) == 3
    for req, res in zip(reqs, out):
        assert res.topology is not None and res.topology.n == req.n
        assert res.complete and res.quality_tier == "full"
    with pytest.deprecated_call():
        legacy = sweep_topologies([12], [24, 18], cfg=FAST)
    assert _support(out[0].topology) == _support(legacy[(12, 24)])
    assert _support(out[2].topology) == _support(legacy[(12, 18)])
    single = solve_topology(reqs[1], cfg=FAST)
    assert _support(out[1].topology) == _support(single.topology)


# =========================================================================
# incumbent semantics under a budget
# =========================================================================

def test_incumbent_monotone_and_final_result():
    solver = AnytimeSolver(TopologyRequest(n=16, r=32, deadline_ms=60_000.0),
                           FAST)
    seen = []
    while (inc := solver.next_improvement()) is not None:
        seen.append(inc)
    assert len(seen) >= 2                   # classics then at least one solve
    r_seq = [inc.r_asym for inc in seen]
    assert all(b <= a for a, b in zip(r_seq, r_seq[1:])), \
        "incumbent quality must be monotone non-increasing in r_asym"
    t_seq = [inc.elapsed_ms for inc in seen]
    assert all(b >= a for a, b in zip(t_seq, t_seq[1:]))
    res = solver.result()
    assert res.r_asym == seen[-1].r_asym
    assert res.improvements == len(seen)
    validate_topology(res.topology, context="anytime final")


def test_expired_budget_returns_release_valid_topology():
    res = solve_topology(TopologyRequest(n=16, r=32), cfg=FAST,
                         budget_ms=1e-3)
    assert not res.complete
    assert res.quality_tier == "classic"
    assert res.reason and "budget" in res.reason
    validate_topology(res.topology, context="expired budget")
    assert check_invariants(res.topology) is None


def test_tight_budget_is_valid_and_reports_curtailment():
    res = solve_topology(TopologyRequest(n=16, r=32), cfg=FAST,
                         budget_ms=40.0)
    assert res.topology is not None
    validate_topology(res.topology, context="tight budget")
    if not res.complete:
        assert res.reason                    # says what was skipped/curtailed


# =========================================================================
# one validation path (satellite: dedup + byte-identical messages)
# =========================================================================

@pytest.mark.parametrize("kw,frag", [
    (dict(n=1, r=4), "need n >= 2"),
    (dict(n=8, r=3), "can never connect"),
    (dict(n=8, r=16, scenario="warp"), "unknown scenario"),
    (dict(n=8, r=16, scenario="node"), "requires node_bandwidths"),
    (dict(n=8, r=16, scenario="node",
          node_bandwidths=np.full(8, np.nan)), "finite and positive"),
    (dict(n=8, r=16, scenario="constraint"), "requires a ConstraintSet"),
    (dict(n=8, r=16, deadline_ms=-5.0), "deadline_ms"),
    (dict(n=8, r=16, restarts=0), "restarts"),
])
def test_validate_request_covers_service_admission(kw, frag):
    bad = validate_request(TopologyRequest(**kw))
    assert bad is not None and frag in bad
    with pytest.raises(ValueError):
        AnytimeSolver(TopologyRequest(**kw), FAST)


def test_scenario_error_messages_stay_context_pinned():
    """The pre-refactor entrypoints kept their exact error texts."""
    with pytest.raises(ValueError) as api_err, pytest.deprecated_call():
        optimize_topology(8, 16, "node")
    assert str(api_err.value) == ("scenario='node' requires node_bandwidths "
                                  "(per-node GB/s profile for Algorithm 1)")
    with pytest.raises(ValueError) as reopt_err:
        from repro.core import make_baseline
        reoptimize_topology(make_baseline("ring", 8), scenario="node")
    assert str(reopt_err.value) == ("scenario='node' re-optimization requires "
                                    "the drifted node_bandwidths profile")
    with pytest.raises(ValueError) as cs_err, pytest.deprecated_call():
        optimize_topology(8, 16, "constraint")
    assert str(cs_err.value) == ("scenario='constraint' requires a "
                                 "ConstraintSet (cs=...)")


def test_old_entrypoints_warn_but_work():
    with pytest.deprecated_call():
        topo = optimize_topology(8, 16, "homo", cfg=FAST)
    assert check_invariants(topo) is None


# =========================================================================
# PhaseProfile (satellite: documented schema + merge)
# =========================================================================

def test_phase_profile_merge_and_dict_roundtrip():
    a = PhaseProfile({"warm": 0.5, "admm": 2.0})
    b = PhaseProfile({"admm": 1.0, "eval": 0.25})
    m = a.merge(b)
    assert m.phases == {"warm": 0.5, "admm": 3.0, "eval": 0.25}
    assert a.phases == {"warm": 0.5, "admm": 2.0}   # merge is non-mutating
    assert m.ms("admm") == 3000.0
    assert m.total_s == pytest.approx(3.75)
    d = m.to_dict()
    assert d == {"warm_s": 0.5, "admm_s": 3.0, "eval_s": 0.25}
    assert PhaseProfile.from_dict(d).phases == m.phases
    # legacy key spellings: *_s is seconds, *_ms is milliseconds
    p = PhaseProfile.from_dict({"queue_s": 1.0, "solve_ms": 500.0})
    assert p.phases == {"queue": 1.0, "solve": 0.5}


def test_solve_topology_fills_legacy_profile_dict():
    prof: dict = {}
    solve_topology(TopologyRequest(n=8, r=16), cfg=FAST, profile=prof)
    assert prof and all(k.endswith("_s") for k in prof)


# =========================================================================
# streaming SA (the stage the budgeted path interleaves)
# =========================================================================

def test_anneal_stream_bit_equals_batched():
    n, iters = 12, 60
    rng = np.random.default_rng(0)
    edges0 = []
    for _ in range(2):
        perm = rng.permutation(n)
        edges0.append(sorted(tuple(sorted((int(perm[i]),
                                           int(perm[(i + 1) % n]))))
                             for i in range(n)))
    ref = anneal_topology_batched(n, edges0, iters=iters, seeds=[3, 4])
    last = None
    for best_edges, costs, t_done in anneal_topology_stream(
            n, edges0, iters=iters, seeds=[3, 4], chunk=17):
        last = (best_edges, t_done)
    assert last is not None and last[1] == iters
    assert [sorted(e) for e in last[0]] == [sorted(e) for e in ref]
