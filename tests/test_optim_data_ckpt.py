"""Optimizers, schedules, data pipeline, checkpointing."""
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.checkpoint import (
    CheckpointCorruptionWarning,
    CheckpointError,
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
)
from repro.data import (
    DataConfig,
    class_balanced_partition,
    make_classification_data,
    synthetic_lm_batch,
)
from repro.optim import (
    apply_updates,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
    sgd_momentum,
    warmup_cosine,
)


# --- optimizers -----------------------------------------------------------

def _quad_problem():
    params = {"w": jnp.array([2.0, -3.0]), "b": jnp.array(1.5)}
    grad = jax.grad(lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2)
    return params, grad


@pytest.mark.parametrize("name,kw", [("sgd", {"weight_decay": 0.0}),
                                     ("adamw", {"weight_decay": 0.0})])
def test_optimizers_descend_quadratic(name, kw):
    params, grad = _quad_problem()
    init, update = make_optimizer(name, 0.1, **kw)
    state = init(params)
    for _ in range(250):
        updates, state = update(grad(params), state, params)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert float(jnp.abs(params["b"])) < 1e-2


def test_sgd_momentum_matches_manual():
    """Paper hyper-params: m ← 0.9 m + (g + wd·p); p ← p − lr·m."""
    params = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    init, update = sgd_momentum(0.1, momentum=0.9, weight_decay=1e-4)
    state = init(params)
    upd, state = update(g, state, params)
    expect_m = g["w"] + 1e-4 * params["w"]
    np.testing.assert_allclose(np.asarray(upd["w"]), -0.1 * expect_m, rtol=1e-6)
    upd2, state = update(g, state, params)
    expect_m2 = 0.9 * expect_m + expect_m
    np.testing.assert_allclose(np.asarray(upd2["w"]), -0.1 * expect_m2, rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, 10, 100)
    lrs = [float(fn(jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] < 0.1 and max(lrs) == pytest.approx(1.0, abs=0.05)
    assert lrs[-1] == pytest.approx(0.1, abs=0.02)


# --- data -----------------------------------------------------------------

def test_lm_batch_deterministic_and_learnable_structure():
    dc = DataConfig(vocab_size=64, seq_len=32, batch_size=4, seed=3)
    a = synthetic_lm_batch(dc, step=5, node=2)
    b = synthetic_lm_batch(dc, step=5, node=2)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = synthetic_lm_batch(dc, step=6, node=2)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    # labels are next tokens with trailing ignore
    np.testing.assert_array_equal(np.asarray(a["labels"])[:, :-1],
                                  np.asarray(a["tokens"])[:, 1:])
    assert (np.asarray(a["labels"])[:, -1] == -100).all()


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 12))
def test_class_balanced_partition_property(n):
    _, y = make_classification_data(num_classes=5, dim=8, samples_per_class=24)
    parts = class_balanced_partition(y, n)
    assert len(parts) == n
    sizes = {len(p) for p in parts}
    assert len(sizes) == 1  # equal sizes
    for p in parts:
        counts = np.bincount(y[p], minlength=5)
        assert (counts == counts[0]).all()  # class-balanced per node
    flat = np.concatenate(parts)
    assert len(np.unique(flat)) == len(flat)  # disjoint


def test_classification_split_same_task():
    Xa, ya = make_classification_data(seed=7, samples_per_class=32)
    Xb, yb = make_classification_data(seed=7, samples_per_class=32,
                                      noise_seed=1234)
    # same means → same class structure, different samples
    assert not np.allclose(Xa, Xb)
    ca = np.stack([Xa[ya == c].mean(0) for c in range(10)])
    cb = np.stack([Xb[yb == c].mean(0) for c in range(10)])
    Xz, yz = make_classification_data(seed=8, samples_per_class=32)
    cz = np.stack([Xz[yz == c].mean(0) for c in range(10)])
    # same-seed class means agree far better than different-task means
    assert np.linalg.norm(ca - cb) < 0.5 * np.linalg.norm(ca - cz)


# --- checkpoint -----------------------------------------------------------

def test_checkpoint_roundtrip_nested():
    tree = {"layers": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "step": jnp.asarray(7, jnp.int32),
            "tup": (jnp.ones((2,)), jnp.zeros((1,), jnp.bool_))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.npz")
        save_checkpoint(path, tree, step=42)
        restored, step = load_checkpoint(path, tree)
        assert step == 42
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises():
    tree = {"w": jnp.ones((2, 3))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.npz")
        save_checkpoint(path, tree)
        with pytest.raises(ValueError):
            load_checkpoint(path, {"w": jnp.ones((3, 2))})


def test_manager_keeps_last_k():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (10, 20, 30, 40):
            mgr.save({"w": jnp.ones(3) * s}, s)
        assert mgr.latest_step() == 40
        files = sorted(os.listdir(d))
        assert files == ["ckpt_30.npz", "ckpt_40.npz"]
        restored, s = mgr.restore({"w": jnp.zeros(3)}, step=30)
        assert s == 30 and float(restored["w"][0]) == 30


def test_manager_keep_must_be_positive():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError):
            CheckpointManager(d, keep=0)


def test_checkpoint_extras_roundtrip_shape_free():
    """Extras restore without template matching — their shapes legitimately
    change across a run (a re-optimized topology has another edge count)."""
    tree = {"w": jnp.ones((2,))}
    extra = {"edges": np.arange(10, dtype=np.int64).reshape(5, 2),
             "key": np.asarray([7, 9], np.uint32),
             "data_step": np.asarray(13, np.int64)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.npz")
        save_checkpoint(path, tree, step=3, extra=extra)
        restored, step, got = load_checkpoint(path, tree, with_extra=True)
        assert step == 3 and set(got) == set(extra)
        for k in extra:
            np.testing.assert_array_equal(got[k], extra[k])
        # the extras channel is invisible to a plain (2-tuple) load
        _, step2 = load_checkpoint(path, tree)
        assert step2 == 3

        mgr = CheckpointManager(d)
        mgr.save(tree, 5, extra={"edges": np.zeros((7, 2), np.int64)})
        _, s, got5 = mgr.restore(tree, with_extra=True)
        assert s == 5 and got5["edges"].shape == (7, 2)


def test_leaf_set_mismatch_is_checkpoint_error():
    tree = {"w": jnp.ones((2,)), "b": jnp.zeros((1,))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.npz")
        save_checkpoint(path, tree)
        with pytest.raises(CheckpointError, match="missing"):
            load_checkpoint(path, {"w": jnp.ones((2,)), "v": jnp.zeros((1,))})
        with pytest.raises(CheckpointError, match="unexpected"):
            load_checkpoint(path, {"w": jnp.ones((2,))})


def test_manager_falls_back_past_corrupt_checkpoint():
    """The restore path of a run that crashed mid-write: the newest file is
    truncated garbage; restore warns and lands on the previous one."""
    tmpl = {"w": jnp.zeros(3)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        mgr.save({"w": jnp.ones(3) * 1}, 1)
        mgr.save({"w": jnp.ones(3) * 2}, 2)
        with open(os.path.join(d, "ckpt_3.npz"), "wb") as f:
            f.write(b"PK\x03\x04 not a real archive")
        with pytest.warns(CheckpointCorruptionWarning):
            restored, s = mgr.restore(tmpl)
        assert s == 2 and float(restored["w"][0]) == 2
        # an explicit step is an explicit ask — no silent fallback
        with pytest.raises(CheckpointError):
            mgr.restore(tmpl, step=3)


def test_manager_falls_back_past_template_drift():
    """A checkpoint from an older code version (different leaf set) is as
    unrestorable as a truncated one — skip it, warn, keep looking."""
    tmpl = {"w": jnp.zeros(3)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save({"w": jnp.ones(3) * 7}, 1)
        save_checkpoint(os.path.join(d, "ckpt_2.npz"),
                        {"w": jnp.ones(3), "stale_extra_leaf": jnp.ones(1)},
                        step=2)
        with pytest.warns(CheckpointCorruptionWarning):
            restored, s = mgr.restore(tmpl)
        assert s == 1 and float(restored["w"][0]) == 7


def test_manager_all_corrupt_returns_none():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        for s in (1, 2):
            with open(os.path.join(d, f"ckpt_{s}.npz"), "wb") as f:
                f.write(b"junk")
        with pytest.warns(CheckpointCorruptionWarning):
            out = mgr.restore({"w": jnp.zeros(3)}, with_extra=True)
        assert out == (None, None, {})
