"""Optimizers, schedules, data pipeline, checkpointing."""
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import (
    DataConfig,
    class_balanced_partition,
    make_classification_data,
    synthetic_lm_batch,
)
from repro.optim import (
    apply_updates,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
    sgd_momentum,
    warmup_cosine,
)


# --- optimizers -----------------------------------------------------------

def _quad_problem():
    params = {"w": jnp.array([2.0, -3.0]), "b": jnp.array(1.5)}
    grad = jax.grad(lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2)
    return params, grad


@pytest.mark.parametrize("name,kw", [("sgd", {"weight_decay": 0.0}),
                                     ("adamw", {"weight_decay": 0.0})])
def test_optimizers_descend_quadratic(name, kw):
    params, grad = _quad_problem()
    init, update = make_optimizer(name, 0.1, **kw)
    state = init(params)
    for _ in range(250):
        updates, state = update(grad(params), state, params)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert float(jnp.abs(params["b"])) < 1e-2


def test_sgd_momentum_matches_manual():
    """Paper hyper-params: m ← 0.9 m + (g + wd·p); p ← p − lr·m."""
    params = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -1.0])}
    init, update = sgd_momentum(0.1, momentum=0.9, weight_decay=1e-4)
    state = init(params)
    upd, state = update(g, state, params)
    expect_m = g["w"] + 1e-4 * params["w"]
    np.testing.assert_allclose(np.asarray(upd["w"]), -0.1 * expect_m, rtol=1e-6)
    upd2, state = update(g, state, params)
    expect_m2 = 0.9 * expect_m + expect_m
    np.testing.assert_allclose(np.asarray(upd2["w"]), -0.1 * expect_m2, rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, 10, 100)
    lrs = [float(fn(jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] < 0.1 and max(lrs) == pytest.approx(1.0, abs=0.05)
    assert lrs[-1] == pytest.approx(0.1, abs=0.02)


# --- data -----------------------------------------------------------------

def test_lm_batch_deterministic_and_learnable_structure():
    dc = DataConfig(vocab_size=64, seq_len=32, batch_size=4, seed=3)
    a = synthetic_lm_batch(dc, step=5, node=2)
    b = synthetic_lm_batch(dc, step=5, node=2)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = synthetic_lm_batch(dc, step=6, node=2)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    # labels are next tokens with trailing ignore
    np.testing.assert_array_equal(np.asarray(a["labels"])[:, :-1],
                                  np.asarray(a["tokens"])[:, 1:])
    assert (np.asarray(a["labels"])[:, -1] == -100).all()


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 12))
def test_class_balanced_partition_property(n):
    _, y = make_classification_data(num_classes=5, dim=8, samples_per_class=24)
    parts = class_balanced_partition(y, n)
    assert len(parts) == n
    sizes = {len(p) for p in parts}
    assert len(sizes) == 1  # equal sizes
    for p in parts:
        counts = np.bincount(y[p], minlength=5)
        assert (counts == counts[0]).all()  # class-balanced per node
    flat = np.concatenate(parts)
    assert len(np.unique(flat)) == len(flat)  # disjoint


def test_classification_split_same_task():
    Xa, ya = make_classification_data(seed=7, samples_per_class=32)
    Xb, yb = make_classification_data(seed=7, samples_per_class=32,
                                      noise_seed=1234)
    # same means → same class structure, different samples
    assert not np.allclose(Xa, Xb)
    ca = np.stack([Xa[ya == c].mean(0) for c in range(10)])
    cb = np.stack([Xb[yb == c].mean(0) for c in range(10)])
    Xz, yz = make_classification_data(seed=8, samples_per_class=32)
    cz = np.stack([Xz[yz == c].mean(0) for c in range(10)])
    # same-seed class means agree far better than different-task means
    assert np.linalg.norm(ca - cb) < 0.5 * np.linalg.norm(ca - cz)


# --- checkpoint -----------------------------------------------------------

def test_checkpoint_roundtrip_nested():
    tree = {"layers": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "step": jnp.asarray(7, jnp.int32),
            "tup": (jnp.ones((2,)), jnp.zeros((1,), jnp.bool_))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.npz")
        save_checkpoint(path, tree, step=42)
        restored, step = load_checkpoint(path, tree)
        assert step == 42
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises():
    tree = {"w": jnp.ones((2, 3))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.npz")
        save_checkpoint(path, tree)
        with pytest.raises(ValueError):
            load_checkpoint(path, {"w": jnp.ones((3, 2))})


def test_manager_keeps_last_k():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (10, 20, 30, 40):
            mgr.save({"w": jnp.ones(3) * s}, s)
        assert mgr.latest_step() == 40
        files = sorted(os.listdir(d))
        assert files == ["ckpt_30.npz", "ckpt_40.npz"]
        restored, s = mgr.restore({"w": jnp.zeros(3)}, step=30)
        assert s == 30 and float(restored["w"][0]) == 30
