"""Sharded ADMM execution layer (core.shard, DESIGN.md §13).

Two tiers:
  - partition-resolution and config-validation tests run in-process on the
    default single device (``resolve_partition`` takes an explicit device
    count, so the dispatch policy is testable without a mesh), plus a
    1-device ``shard_map`` parity check — the sharded math itself does not
    need more than one device to be exercised.
  - the multi-device parity suite runs in a subprocess with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (same pattern as
    test_sharded_runtime.py — the main pytest process must keep the default
    single device). Unlike test_sharded_runtime.py this suite needs only
    ``jax.experimental.shard_map``, which the pinned jax provides, so it
    runs rather than skips here.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.engine import ADMMConfig, init_state, make_homo_spec, solve_spec
from repro.core.shard import (
    EDGE_PARTITION_MIN_N, resolve_partition, solve_spec_sharded)


# ---------------------------------------------------------------------------
# partition="auto" dispatch policy (pure host logic, explicit ndev)
# ---------------------------------------------------------------------------

def test_resolve_partition_auto_policy():
    big = EDGE_PARTITION_MIN_N
    # single device: always the engine path
    assert resolve_partition("auto", big, None, ndev=1) == "none"
    assert resolve_partition("auto", big, 16, ndev=1) == "none"
    # batch fills the devices → instance parallelism wins (no collectives)
    assert resolve_partition("auto", big, 8, ndev=8) == "instances"
    assert resolve_partition("auto", 64, 8, ndev=8) == "instances"
    # large single instance → edge partitioning
    assert resolve_partition("auto", big, None, ndev=8) == "edges"
    assert resolve_partition("auto", big, 4, ndev=8) == "edges"
    # small single instance: collectives would dominate
    assert resolve_partition("auto", big - 1, None, ndev=8) == "none"


def test_resolve_partition_explicit_passthrough():
    # explicit modes pass through un-second-guessed
    assert resolve_partition("edges", 8, None, ndev=1) == "edges"
    assert resolve_partition("instances", 8, 2, ndev=1) == "instances"
    assert resolve_partition("none", 10_000, 64, ndev=8) == "none"
    with pytest.raises(ValueError, match="unknown partition"):
        resolve_partition("Edges", 64, None, ndev=8)


def test_admm_config_validates_partition():
    with pytest.raises(ValueError, match="unknown partition"):
        make_homo_spec(8, 10, ADMMConfig(partition="shard"))


def test_sharded_rejects_unsupported_solver():
    cfg = ADMMConfig(max_iters=10, solver="kkt_bicgstab")
    spec = make_homo_spec(8, 10, cfg)
    st = init_state(spec, jnp.zeros(spec.m), 0.5)
    with pytest.raises(ValueError, match="schur_cg"):
        solve_spec_sharded(spec, st, cfg, ndev=1)


# ---------------------------------------------------------------------------
# 1-device parity: the shard_map path must reproduce the engine exactly
# (no cross-device reassociation on a singleton mesh)
# ---------------------------------------------------------------------------

def test_sharded_solve_single_device_parity():
    cfg = ADMMConfig(max_iters=60, check_every=10)
    spec = make_homo_spec(12, 20, cfg)
    rng = np.random.default_rng(0)
    g0 = np.abs(rng.normal(size=spec.m)) * 0.1
    st = init_state(spec, jnp.asarray(g0), 0.5)
    ref = solve_spec(spec, st, cfg)
    sh = solve_spec_sharded(spec, st, cfg, ndev=1)
    np.testing.assert_allclose(sh.g, ref.g, atol=1e-12)
    assert abs(sh.lam_tilde - ref.lam_tilde) < 1e-10
    assert sh.iters == ref.iters
    np.testing.assert_allclose(sh.residual, ref.residual, rtol=1e-9)


# ---------------------------------------------------------------------------
# 8-device parity suite (subprocess; XLA_FLAGS must precede jax init)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import (ADMMConfig, make_homo_spec, make_hetero_spec,
                               init_state, solve_spec, solve_batched_spec)
from repro.core.shard import (resolve_partition, solve_spec_sharded,
                              solve_batched_spec_sharded)

assert jax.device_count() == 8, jax.device_count()
assert resolve_partition("auto", 1024) == "edges"
rng = np.random.default_rng(0)

# --- homo, fp64 exact stack: drift is pure psum reassociation ------------
cfg = ADMMConfig(max_iters=60, check_every=10)
spec = make_homo_spec(24, 60, cfg)
g0 = np.abs(rng.normal(size=spec.m)) * 0.1
st = init_state(spec, jnp.asarray(g0), 0.5)
ref = solve_spec(spec, st, cfg)
sh = solve_spec_sharded(spec, st, cfg)
np.testing.assert_allclose(sh.g, ref.g, atol=1e-9)
assert abs(sh.lam_tilde - ref.lam_tilde) < 1e-9
print("HOMO_PARITY_OK", np.abs(sh.g - ref.g).max())

# --- homo, large-n stack pieces: fp32 + inexact CG + jacobi + NS ---------
cfg2 = ADMMConfig(max_iters=60, check_every=10, dtype="float32",
                  cg_inexact=True, precond="jacobi",
                  psd_backend="newton_schulz", psd_iters=16)
spec2 = make_homo_spec(24, 60, cfg2)
st2 = init_state(spec2, jnp.asarray(g0), 0.5)
ref2 = solve_spec(spec2, st2, cfg2)
sh2 = solve_spec_sharded(spec2, st2, cfg2)
np.testing.assert_allclose(sh2.g, ref2.g, atol=5e-4)
print("FAST_STACK_PARITY_OK", np.abs(sh2.g - ref2.g).max())

# --- hetero, inequality capacities + jacobi ------------------------------
n = 16
m = n * (n - 1) // 2
M = rng.integers(0, 2, size=(5, m)).astype(np.float64)
e_cap = M.sum(axis=1) * 0.4
cfg3 = ADMMConfig(max_iters=60, check_every=10, precond="jacobi")
spec3 = make_hetero_spec(n, 30, M, e_cap, cfg3, equality=False)
g0h = np.abs(rng.normal(size=m)) * 0.1
st3 = init_state(spec3, jnp.asarray(g0h), 0.5)
ref3 = solve_spec(spec3, st3, cfg3)
sh3 = solve_spec_sharded(spec3, st3, cfg3)
np.testing.assert_allclose(sh3.g, ref3.g, atol=1e-8)
np.testing.assert_array_equal(sh3.z, ref3.z)  # binary top-r rank-exact
print("HETERO_PARITY_OK", np.abs(sh3.g - ref3.g).max())

# --- hetero, equality capacities (pinned s-block) ------------------------
cfg4 = ADMMConfig(max_iters=40, check_every=10)
spec4 = make_hetero_spec(n, 30, M, M @ (g0h > 0.05), cfg4, equality=True)
st4 = init_state(spec4, jnp.asarray(g0h), 0.5)
ref4 = solve_spec(spec4, st4, cfg4)
sh4 = solve_spec_sharded(spec4, st4, cfg4)
np.testing.assert_allclose(sh4.g, ref4.g, atol=1e-8)
print("HETERO_EQ_PARITY_OK", np.abs(sh4.g - ref4.g).max())

# --- instance partitioning: bit-exact (same compiled math, moved data) ---
B = 8
g0s = np.abs(rng.normal(size=(B, spec.m))) * 0.1
states = jax.vmap(lambda g, l: init_state(spec, g, l))(
    jnp.asarray(g0s), jnp.full(B, 0.5))
ref_b = solve_batched_spec(spec, states, cfg)
sh_b = solve_batched_spec_sharded(spec, states, cfg)
for a, b in zip(ref_b, sh_b):
    np.testing.assert_array_equal(a.g, b.g)
    assert a.iters == b.iters
print("INSTANCES_PARITY_OK")

# --- non-divisible batch: padding is added and dropped -------------------
B2 = 5
g0s2 = np.abs(rng.normal(size=(B2, spec.m))) * 0.1
states2 = jax.vmap(lambda g, l: init_state(spec, g, l))(
    jnp.asarray(g0s2), jnp.full(B2, 0.5))
ref_b2 = solve_batched_spec(spec, states2, cfg)
sh_b2 = solve_batched_spec_sharded(spec, states2, cfg)
assert len(sh_b2) == B2
for a, b in zip(ref_b2, sh_b2):
    np.testing.assert_array_equal(a.g, b.g)
print("INSTANCES_PAD_OK")
"""

MARKERS = ("HOMO_PARITY_OK", "FAST_STACK_PARITY_OK", "HETERO_PARITY_OK",
           "HETERO_EQ_PARITY_OK", "INSTANCES_PARITY_OK", "INSTANCES_PAD_OK")


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(jax.experimental, "shard_map"),
    reason="requires jax.experimental.shard_map (core.shard's mapping API)")
def test_sharded_admm_multi_device_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for marker in MARKERS:
        assert marker in res.stdout, res.stdout + "\n" + res.stderr
