"""edge_laplacian Pallas pair vs ref.py vs the engine's pure-JAX operators
(tests/test_kernels.py style: shape/dtype sweeps, interpret mode,
assert_allclose against the oracle). Lives in its own module so it collects
without the optional ``hypothesis`` dependency."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine as E
from repro.core.admm import ADMMConfig
from repro.core.graph import all_edges


def _edges(n):
    edges = all_edges(n)
    ei = jnp.array([i for i, _ in edges], dtype=jnp.int32)
    ej = jnp.array([j for _, j in edges], dtype=jnp.int32)
    return ei, ej, len(edges)


@pytest.mark.parametrize("n", [2, 5, 8, 16, 33])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_edge_laplacian_kernel_vs_ref(n, dtype):
    from repro.kernels.edge_laplacian import ops, ref

    ei, ej, m = _edges(n)
    g = jax.random.uniform(jax.random.PRNGKey(n), (m,)).astype(dtype)
    out = ops.edge_laplacian(g, ei, ej, n, use_kernel=True)
    expect = ref.edge_laplacian(g, ei, ej, n)
    assert out.shape == (n, n) and out.dtype == dtype
    tol = 1e-12 if dtype == jnp.float64 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               np.asarray(expect, np.float64), atol=tol)
    # Laplacian invariants: symmetric, zero row sums
    np.testing.assert_allclose(np.asarray(out), np.asarray(out).T, atol=tol)
    np.testing.assert_allclose(np.asarray(out).sum(1), 0.0, atol=1e-4)


@pytest.mark.parametrize("n", [5, 8, 16, 33])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_edge_quadform_kernel_vs_ref(n, dtype):
    from repro.kernels.edge_laplacian import ops, ref

    ei, ej, m = _edges(n)
    P = jax.random.normal(jax.random.PRNGKey(n + 1), (n, n)).astype(dtype)
    out = ops.edge_quadform(P, ei, ej, use_kernel=True)
    expect = ref.edge_quadform(P, ei, ej)
    assert out.shape == (m,) and out.dtype == dtype
    tol = 1e-12 if dtype == jnp.float64 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               np.asarray(expect, np.float64), atol=tol)


def test_edge_quadform_partial_edge_list():
    """The quadform gather is index-driven — it must also serve subset edge
    lists (e.g. BCube admissible edges)."""
    from repro.kernels.edge_laplacian import ops, ref

    n = 12
    rng = np.random.default_rng(0)
    ei_all, ej_all, m = _edges(n)
    keep = rng.random(m) < 0.4
    ei = jnp.asarray(np.asarray(ei_all)[keep])
    ej = jnp.asarray(np.asarray(ej_all)[keep])
    P = jnp.asarray(rng.normal(size=(n, n)))
    np.testing.assert_allclose(
        np.asarray(ops.edge_quadform(P, ei, ej, use_kernel=True)),
        np.asarray(ref.edge_quadform(P, ei, ej)), atol=1e-12)


def test_ref_matches_engine_operators():
    """ref.py reproduces the engine's ``_L_of_g``/``_edge_quadform`` (both
    the fused-gather default and the scatter fallback) on random weights."""
    from repro.kernels.edge_laplacian import ref

    n, r = 9, 12
    spec = E.make_homo_spec(n, r, ADMMConfig())
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.random(spec.m))
    L_ref = ref.edge_laplacian(g, spec.ei, spec.ej, n)
    np.testing.assert_allclose(np.asarray(E._L_of_g(spec, g)),
                               np.asarray(L_ref), atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(E._L_of_g(spec.replace(lidx=None), g)),  # scatter fallback
        np.asarray(L_ref), atol=1e-12)
    P = jnp.asarray(rng.normal(size=(n, n)))
    np.testing.assert_allclose(np.asarray(E._edge_quadform(spec, P)),
                               np.asarray(ref.edge_quadform(P, spec.ei, spec.ej)),
                               atol=1e-12)


def test_engine_edge_kernel_dispatch():
    """A spec with ``edge_kernel=True`` routes the ADMM step through the
    Pallas pair and reproduces the default step."""
    n, r = 8, 12
    rng = np.random.default_rng(2)
    g0 = 0.2 * rng.random(n * (n - 1) // 2)
    spec_d = E.make_homo_spec(n, r, ADMMConfig())
    spec_k = E.make_homo_spec(n, r, ADMMConfig(edge_kernel=True))
    st_d, res_d = E.step(spec_d, E.init_state(spec_d, jnp.asarray(g0), 0.4))
    st_k, res_k = E.step(spec_k, E.init_state(spec_k, jnp.asarray(g0), 0.4))
    for a, b in zip(jax.tree.leaves(st_d.X), jax.tree.leaves(st_k.X)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-9)
    assert float(res_d) == pytest.approx(float(res_k), rel=1e-9)
