"""End-to-end optimize_topology + consensus simulation behaviour."""
import numpy as np
import pytest

from repro.core import ADMMConfig, BATopoConfig, optimize_topology
from repro.core.bandwidth import homo_edge_bandwidth, min_edge_bandwidth, t_epoch, t_iter
from repro.core.consensus import simulate_consensus, time_to_error
from repro.core.topologies import ring, torus2d

_FAST = BATopoConfig(admm=ADMMConfig(max_iters=250), sa_iters=400, polish_iters=250)


def test_optimize_homo_beats_ring():
    topo = optimize_topology(12, 18, "homo", cfg=_FAST)
    topo.validate()
    assert topo.r <= 18
    assert topo.r_asym() < ring(12).r_asym()


def test_optimize_node_respects_allocation():
    b = np.array([9.76] * 4 + [3.25] * 4)
    topo = optimize_topology(8, 12, "node", node_bandwidths=b, cfg=_FAST)
    topo.validate()
    # slow nodes must not exceed their Algorithm-1 allocation
    alloc = np.asarray(topo.meta["alloc_e"])
    assert np.all(topo.deg <= alloc)


def test_optimize_topology_batched_restarts():
    """restarts > 1 go through the vmapped batched solve and still return a
    valid connected topology."""
    cfg = BATopoConfig(admm=ADMMConfig(max_iters=150), sa_iters=250,
                       polish_iters=200, restarts=2)
    topo = optimize_topology(10, 15, "homo", cfg=cfg)
    topo.validate()
    assert topo.r <= 15
    assert "r_asym" in topo.meta


def test_sweep_topologies_grid():
    from repro.core import sweep_topologies

    cfg = BATopoConfig(admm=ADMMConfig(max_iters=100), sa_iters=200,
                       polish_iters=150)
    out = sweep_topologies([8], [10, 12], cfg=cfg)
    assert set(out) == {(8, 10), (8, 12)}
    for (n, r), topo in out.items():
        assert topo is not None
        topo.validate()
        assert topo.r <= r


def test_consensus_rate_matches_r_asym():
    """Empirical per-iteration error decay ≈ r_asym (Eq. 2 ↔ Eq. 3)."""
    topo = torus2d(16)
    tr = simulate_consensus(topo, iters=80, dim=8, seed=1)
    # asymptotic ratio measured before the fp64 floor (0.6^150 ≈ 1e-33)
    k0, k1 = 20, 60
    rate = (tr.errors[k1] / tr.errors[k0]) ** (1.0 / (k1 - k0))
    assert abs(rate - topo.r_asym()) < 0.02


def test_time_model_eq34_eq35():
    topo = ring(16)
    bw = homo_edge_bandwidth(topo, 9.76)
    bmin = min_edge_bandwidth(bw)
    assert bmin == pytest.approx(9.76 / 2)  # ring degree 2
    assert t_iter(bmin) == pytest.approx(2 * 5.01)
    assert t_epoch(bmin, 10) == pytest.approx((2 * 5.01 + 15.21) * 10)


def test_time_to_error_monotone_in_bandwidth():
    topo = torus2d(16)
    fast = simulate_consensus(topo, iters=400, b_min=9.76)
    slow = simulate_consensus(topo, iters=400, b_min=1.0)
    assert time_to_error(fast, 1e-4) < time_to_error(slow, 1e-4)
