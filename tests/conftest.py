"""Degrade gracefully when optional dev dependencies are absent.

``hypothesis`` is a dev-only dependency (see pyproject.toml). If it is not
installed, the property-test modules that import it are skipped at
collection instead of erroring the whole run.
"""
from __future__ import annotations

import importlib.util
import pathlib
import re
import warnings

collect_ignore: list[str] = []

_IMPORTS_HYPOTHESIS = re.compile(r"^\s*(from|import)\s+hypothesis\b", re.M)

if importlib.util.find_spec("hypothesis") is None:
    _here = pathlib.Path(__file__).parent
    collect_ignore = sorted(
        p.name for p in _here.glob("test_*.py")
        if _IMPORTS_HYPOTHESIS.search(p.read_text(encoding="utf-8"))
    )
    if collect_ignore:
        warnings.warn(
            "hypothesis is not installed — skipping property-test modules: "
            + ", ".join(collect_ignore),
            stacklevel=1,
        )
