"""DSGD runtime: schedule decomposition, gossip equivalence, training steps."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced_for_smoke
from repro.core import make_baseline, optimize_topology, BATopoConfig
from repro.core.admm import ADMMConfig
from repro.core.graph import Topology, weight_matrix_from_weights
from repro.data import DataConfig, synthetic_lm_batch
from repro.dsgd import (
    allreduce_train_step,
    bytes_per_sync,
    dsgd_train_step,
    gossip_sim,
    gossip_sim_tree,
    init_dsgd_state,
    reconstruct_weight_matrix,
    schedule_from_topology,
)
from repro.dsgd.schedule import _edge_color
from repro.optim import sgd_momentum


def _random_topology(n: int, extra: int, seed: int) -> Topology:
    """Random connected graph: spanning tree + ``extra`` chords."""
    rng = np.random.default_rng(seed)
    edges = set()
    order = rng.permutation(n)
    for a, b in zip(order[:-1], order[1:]):
        edges.add((min(a, b), max(a, b)))
    while len(edges) < min(n - 1 + extra, n * (n - 1) // 2):
        i, j = rng.integers(0, n, 2)
        if i != j:
            edges.add((min(i, j), max(i, j)))
    edges = sorted(edges)
    from repro.core.weights import metropolis_weights
    g = metropolis_weights(n, edges)
    return Topology(n, edges, g, name=f"rand{n}")


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 24), extra=st.integers(0, 20), seed=st.integers(0, 10_000))
def test_schedule_reconstructs_W_property(n, extra, seed):
    """Property: matching-round decomposition is exact for ANY connected
    weighted topology (the gossip runtime's core invariant)."""
    topo = _random_topology(n, extra, seed)
    sched = schedule_from_topology(topo)
    W = weight_matrix_from_weights(n, topo.edges, topo.g)
    np.testing.assert_allclose(reconstruct_weight_matrix(sched), W, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 24), extra=st.integers(0, 20), seed=st.integers(0, 10_000))
def test_edge_coloring_is_proper_matching(n, extra, seed):
    topo = _random_topology(n, extra, seed)
    matchings = _edge_color(n, list(topo.edges))
    seen = set()
    for matching in matchings:
        nodes = [x for e in matching for x in e]
        assert len(nodes) == len(set(nodes)), "round is not a matching"
        seen.update(map(tuple, matching))
    assert seen == set(map(tuple, topo.edges))
    deg = sched_deg = np.zeros(n, int)
    for i, j in topo.edges:
        deg[i] += 1
        deg[j] += 1
    assert len(matchings) <= 2 * deg.max() - 1  # greedy coloring bound


def test_gossip_sim_matches_matmul():
    topo = make_baseline("exponential", 8)
    W = jnp.asarray(weight_matrix_from_weights(8, topo.edges, topo.g), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 5, 3))
    out = gossip_sim(x, W)
    expect = jnp.einsum("ij,jkl->ikl", W, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_gossip_kernel_path_matches_plain():
    topo = make_baseline("ring", 6)
    W = jnp.asarray(weight_matrix_from_weights(6, topo.edges, topo.g), jnp.float32)
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (6, 130)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (6, 4, 7))}
    plain = gossip_sim_tree(tree, W, use_kernel=False)
    kern = gossip_sim_tree(tree, W, use_kernel=True)
    for k in tree:
        np.testing.assert_allclose(np.asarray(plain[k]), np.asarray(kern[k]),
                                   atol=1e-5)


def test_bytes_per_sync_sparser_than_allreduce():
    topo = optimize_topology(8, 12, "homo",
                             cfg=BATopoConfig(sa_iters=150,
                                              admm=ADMMConfig(max_iters=40)))
    sched = schedule_from_topology(topo)
    t = bytes_per_sync(sched, param_bytes=10**6)
    assert t["total"] == 2 * len(topo.edges) * 10**6


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = reduced_for_smoke(get_arch("smollm-135m"))
    n = 4
    topo = make_baseline("ring", n)
    opt_init, opt_update = sgd_momentum(0.05)
    state = init_dsgd_state(jax.random.PRNGKey(0), cfg, n, opt_init)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2)
    per = [synthetic_lm_batch(dc, 0, node=i) for i in range(n)]
    batch = {k: jnp.stack([b[k] for b in per]) for k in per[0]}
    return cfg, n, topo, opt_init, opt_update, state, batch


def test_dsgd_step_decreases_loss_and_keeps_consensus(smoke_setup):
    cfg, n, topo, opt_init, opt_update, state, batch = smoke_setup
    step = dsgd_train_step(cfg, topo, opt_update)
    losses = []
    st = state
    for _ in range(4):
        st, m = step(st, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]
    assert float(m["consensus_err"]) < 1.0  # bounded by gossip


def test_allreduce_keeps_workers_identical(smoke_setup):
    cfg, n, topo, opt_init, opt_update, state, batch = smoke_setup
    step = allreduce_train_step(cfg, n, opt_update)
    st, m = step(state, batch)
    assert float(m["consensus_err"]) < 1e-3


def test_dsgd_matches_allreduce_on_complete_graph(smoke_setup):
    """Gossip with W = 11ᵀ/n IS all-reduce — the two step builders must agree."""
    cfg, n, _, opt_init, opt_update, state, batch = smoke_setup
    from repro.core.graph import all_edges
    edges = all_edges(n)
    g = np.full(len(edges), 1.0 / n)
    complete = Topology(n, edges, g, name="complete")
    s1, _ = dsgd_train_step(cfg, complete, opt_update)(state, batch)
    s2, _ = allreduce_train_step(cfg, n, opt_update)(state, batch)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)
