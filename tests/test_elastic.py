"""Elastic runtime (DESIGN.md §16): fault-free bit-exactness, watchdog
membership, retry ladder, hot-swap without retrace, crash-safe resume."""
import json
import os
import signal
import subprocess
import sys
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced_for_smoke
from repro.core import make_baseline
from repro.core.graph import weight_matrix_from_weights
from repro.core.reopt import DriftPolicy, ReoptResult
from repro.data import DataConfig, synthetic_lm_batch
from repro.dsgd import (
    ElasticHooks,
    ElasticRuntime,
    ElasticSpec,
    degrade_matrix,
    drift_profile,
    dsgd_train_step,
    init_dsgd_state,
    make_chaos,
    make_elastic_train_step,
    no_chaos,
    node_step_latency_ms,
)
from repro.optim import sgd_momentum

N = 4


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_for_smoke(get_arch("smollm-135m"))
    topo = make_baseline("ring", N)
    opt_init, opt_update = sgd_momentum(0.05)
    state = init_dsgd_state(jax.random.PRNGKey(0), cfg, N, opt_init)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2)
    step_fn = make_elastic_train_step(cfg, opt_update)
    return cfg, topo, opt_update, state, dc, step_fn


def batch_at(dc, step):
    per = [synthetic_lm_batch(dc, step, node=i) for i in range(N)]
    return {k: jnp.stack([b[k] for b in per]) for k in per[0]}


def leaves_equal(a, b):
    return all(np.asarray(x).tobytes() == np.asarray(y).tobytes()
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# --- fault-free bit-exactness ---------------------------------------------

def test_fault_free_elastic_is_bit_exact(setup):
    """With all-clear masks the elastic step IS dsgd_train_step, bitwise —
    params, optimizer state and every metric over several rounds."""
    cfg, topo, opt_update, state, dc, step_fn = setup
    legacy = dsgd_train_step(cfg, topo, opt_update)
    rt = ElasticRuntime(cfg, ElasticSpec(chaos=no_chaos(3, N), reopt=False),
                        topo, opt_update, step_fn=step_fn)
    es = rt.make_state(topo)
    s1 = s2 = state
    for t in range(3):
        b = batch_at(dc, t)
        s1, m1 = legacy(s1, b)
        s2, m2, rep = rt.round(s2, es, b)
        for k in ("loss", "loss_max", "consensus_err"):
            assert np.asarray(m1[k]).tobytes() == np.asarray(m2[k]).tobytes(), k
        assert leaves_equal(s1.params, s2.params)
        assert leaves_equal(s1.opt, s2.opt)
        assert not rep.dropped.any() and rep.attempts == 1


# --- watchdog + membership -------------------------------------------------

def test_watchdog_drops_straggler_and_survivors_stay_row_stochastic(setup):
    """A node whose modeled latency blows past the deadline is dropped from
    the round's exchange; the degraded matrix is row-stochastic on the
    survivors and zero on the non-participant's row."""
    cfg, topo, opt_update, state, dc, step_fn = setup
    chaos = no_chaos(2, N)
    strag = chaos.straggler.copy()
    strag[0, 2] = 50.0                       # node 2 is 50× slow this round
    chaos = type(chaos)(alive=chaos.alive, link_up=chaos.link_up,
                        straggler=strag, bandwidth=chaos.bandwidth)
    spec = ElasticSpec(chaos=chaos, deadline_factor=2.0, reopt=False)
    rt = ElasticRuntime(cfg, spec, topo, opt_update, step_fn=step_fn)
    es = rt.make_state(topo)
    _, _, rep = rt.round(state, es, batch_at(dc, 0))
    assert rep.dropped[2] and rep.dropped.sum() == 1
    assert rep.round_ms == pytest.approx(rep.deadline_ms)  # capped, not 50×

    mix = (rep.alive & ~rep.dropped).astype(np.float32)
    W = jnp.asarray(weight_matrix_from_weights(N, topo.edges, topo.g),
                    jnp.float32)
    Wd = np.asarray(degrade_matrix(W, jnp.asarray(mix),
                                   jnp.ones((N, N), jnp.float32)))
    np.testing.assert_allclose(Wd[mix > 0].sum(axis=1), 1.0, atol=1e-6)
    assert (Wd[2] == 0).all() and (Wd[:, 2] == 0).all()


def test_dead_node_freezes_params_and_opt(setup):
    """A churned-out worker's params AND optimizer state are bitwise frozen;
    it rejoins at the frozen state (metrics exclude it meanwhile)."""
    cfg, topo, opt_update, state, dc, step_fn = setup
    alive = np.ones((3, N), np.float32)
    alive[0, 1] = alive[1, 1] = 0.0          # node 1 dead for rounds 0-1
    ch = no_chaos(3, N)
    chaos = type(ch)(alive=alive, link_up=ch.link_up,
                     straggler=ch.straggler, bandwidth=ch.bandwidth)
    rt = ElasticRuntime(cfg, ElasticSpec(chaos=chaos, reopt=False), topo,
                        opt_update, step_fn=step_fn)
    es = rt.make_state(topo)
    pick = lambda tree: jax.tree.map(lambda x: np.asarray(x[1]), tree)
    p0, o0 = pick(state.params), pick(state.opt)
    st, m, _ = rt.round(state, es, batch_at(dc, 0))
    assert leaves_equal(pick(st.params), p0)
    assert leaves_equal(pick(st.opt), o0)
    assert float(m["n_alive"]) == N - 1
    st2, _, _ = rt.round(st, es, batch_at(dc, 1))
    assert leaves_equal(pick(st2.params), p0)    # still frozen
    st3, _, rep3 = rt.round(st2, es, batch_at(dc, 2))
    assert rep3.alive[1]                          # rejoined this round
    assert not leaves_equal(pick(st3.params), p0)  # training again


def test_node_latency_model_prices_slow_links(setup):
    cfg, topo, _, _, _, _ = setup
    chaos = no_chaos(1, N)
    bw = chaos.bandwidth.copy()
    bw[0, 0] = 0.5                           # node 0's NIC collapses
    chaos = type(chaos)(alive=chaos.alive, link_up=chaos.link_up,
                        straggler=chaos.straggler, bandwidth=bw)
    lat = node_step_latency_ms(topo, chaos, 0)
    assert lat[0] > lat[2]                   # slow NIC → slower round
    ring_nbrs = {j for e in topo.edges if 0 in e for j in e if j != 0}
    assert ring_nbrs == {1, 3}
    for j in ring_nbrs:                      # its neighbors wait on the edge
        assert lat[j] > lat[2]


# --- retry ladder ----------------------------------------------------------

class RecordingHooks(ElasticHooks):
    """Default pass-through hook that records the attempt trail."""

    def __init__(self):
        self.calls = []

    def on_attempt(self, step, attempt, batch):
        self.calls.append((step, attempt))
        return batch


def test_retry_ladder_recovers_from_poisoned_round(setup):
    cfg, topo, opt_update, state, dc, step_fn = setup
    calls = {"n": 0}

    def flaky_step(st, b, W, alive, link, mix):       # NaN loss on attempt 0
        calls["n"] += 1
        new_st, m = step_fn(st, b, W, alive, link, mix)
        if calls["n"] == 1:
            m = dict(m, loss=jnp.float32(np.nan))
        return new_st, m

    hooks = RecordingHooks()
    rt = ElasticRuntime(cfg, ElasticSpec(chaos=no_chaos(1, N), reopt=False,
                                         max_round_retries=1),
                        topo, opt_update, step_fn=flaky_step, hooks=hooks)
    es = rt.make_state(topo)
    st, m, rep = rt.round(state, es, batch_at(dc, 0))
    assert rep.attempts == 2
    assert hooks.calls == [(0, 0), (0, 1)]
    assert [r.outcome for r in rep.rungs] == ["non_finite", "ok"]
    assert np.isfinite(float(m["loss"]))
    assert not leaves_equal(st.params, state.params)


def test_retry_ladder_exhausted_freezes_round(setup):
    cfg, topo, opt_update, state, dc, step_fn = setup

    def always_nan(st, b, W, alive, link, mix):
        new_st, m = step_fn(st, b, W, alive, link, mix)
        return new_st, dict(m, loss=jnp.float32(np.nan))

    rt = ElasticRuntime(cfg, ElasticSpec(chaos=no_chaos(1, N), reopt=False,
                                         max_round_retries=1),
                        topo, opt_update, step_fn=always_nan)
    es = rt.make_state(topo)
    st, m, rep = rt.round(state, es, batch_at(dc, 0))
    assert rep.attempts == 2
    assert rep.rungs[-1].rung == "freeze"
    assert np.isnan(float(m["loss"]))
    assert leaves_equal(st.params, state.params)      # round skipped
    assert int(st.step) == int(state.step) + 1        # clock still advances


# --- drift → reopt → hot-swap ---------------------------------------------

def drifting_chaos(steps):
    bw = drift_profile(steps, N, steps // 2, 9.76, 2, 1.0)
    return make_chaos(steps, N, seed=0, bandwidth=bw)


def test_reopt_adopts_new_topology_without_retrace(setup):
    """The NIC collapse fires the detector, the warm re-solve lands, the new
    topology activates after the lag — all through ONE jit trace."""
    cfg, topo, opt_update, state, dc, _ = setup
    step_fn = make_elastic_train_step(cfg, opt_update)   # fresh cache to count
    spec = ElasticSpec(chaos=drifting_chaos(8), activation_lag_steps=2,
                       drift=DriftPolicy(cooldown_steps=8))
    rt = ElasticRuntime(cfg, spec, topo, opt_update, step_fn=step_fn)
    es = rt.make_state(topo)
    st = state
    swaps = []
    for t in range(8):
        st, _, rep = rt.round(st, es, batch_at(dc, t))
        if rep.swapped:
            swaps.append(t)
    assert es.reopts == 1 and es.adopted == 1
    assert swaps == [4 + 2]                 # trigger@4, lag 2
    assert es.topology.name != topo.name
    assert step_fn._cache_size() == 1       # hot-swap never retraced


def test_reopt_failure_keeps_incumbent_with_reason(setup, monkeypatch):
    cfg, topo, opt_update, state, dc, step_fn = setup
    import repro.dsgd.elastic as elastic_mod

    def failing_reopt(incumbent, **kw):
        return ReoptResult(topology=incumbent, reoptimized=False, attempts=2,
                           fallback_reason="warm: non_finite; cold: error",
                           time_to_reopt_s=0.01, r_asym_before=0.5,
                           r_asym_after=0.5)

    monkeypatch.setattr(elastic_mod, "reoptimize_topology", failing_reopt)
    spec = ElasticSpec(chaos=drifting_chaos(6),
                       drift=DriftPolicy(cooldown_steps=6))
    rt = ElasticRuntime(cfg, spec, topo, opt_update, step_fn=step_fn)
    es = rt.make_state(topo)
    st = state
    for t in range(6):
        st, _, _ = rt.round(st, es, batch_at(dc, t))
    assert es.reopts == 1 and es.adopted == 0
    assert es.topology is topo              # incumbent untouched
    keep = [e for e in es.events if e["event"] == "keep_incumbent"]
    assert keep and "cold" in keep[0]["reason"]


def test_reopt_budget_window_passes_budget_ms(setup, monkeypatch):
    """reopt_budget="window" budgets the re-solve to the adoption window
    (lag x modeled fault-free round time); a float passes through as-is;
    the default stays unbudgeted (budget_ms=None)."""
    cfg, topo, opt_update, state, dc, step_fn = setup
    import repro.dsgd.elastic as elastic_mod
    from repro.dsgd.elastic import fault_free_round_ms

    captured = []

    def capture_reopt(incumbent, **kw):
        captured.append(kw)
        return ReoptResult(topology=incumbent, reoptimized=False, attempts=1,
                           fallback_reason="stub", time_to_reopt_s=0.0,
                           r_asym_before=0.5, r_asym_after=0.5)

    monkeypatch.setattr(elastic_mod, "reoptimize_topology", capture_reopt)
    for budget, lag in ((None, 1), ("window", 2), (123.5, 1)):
        spec = ElasticSpec(chaos=drifting_chaos(6),
                           drift=DriftPolicy(cooldown_steps=6),
                           reopt_budget=budget, activation_lag_steps=lag)
        rt = ElasticRuntime(cfg, spec, topo, opt_update, step_fn=step_fn)
        es = rt.make_state(topo)
        st = state
        for t in range(6):
            st, _, _ = rt.round(st, es, batch_at(dc, t))
        assert es.reopts == 1

    none_kw, window_kw, float_kw = captured
    assert none_kw["budget_ms"] is None
    assert float_kw["budget_ms"] == 123.5
    bw = window_kw["node_bandwidths"]        # drifted profile at the trigger
    expected = 2 * fault_free_round_ms(topo, np.asarray(bw))
    assert window_kw["budget_ms"] == pytest.approx(expected)


def test_elastic_state_extras_roundtrip(setup):
    cfg, topo, opt_update, state, dc, step_fn = setup
    spec = ElasticSpec(chaos=drifting_chaos(8), activation_lag_steps=3)
    rt = ElasticRuntime(cfg, spec, topo, opt_update, step_fn=step_fn)
    es = rt.make_state(topo, seed=5)
    st = state
    for t in range(5):                      # past the trigger, pending alive
        st, _, _ = rt.round(st, es, batch_at(dc, t))
    assert es.pending is not None
    es2 = rt.from_extras(rt.to_extras(es), name=es.topology.name)
    assert es2.data_step == es.data_step
    assert np.asarray(es2.key).tobytes() == np.asarray(es.key).tobytes()
    assert es2.pending[0] == es.pending[0]
    assert es2.pending[1].edges == es.pending[1].edges
    assert es2.detector.last_trigger == es.detector.last_trigger
    np.testing.assert_array_equal(es2.detector.base_bandwidth,
                                  es.detector.base_bandwidth)
    assert es2.topology.edges == es.topology.edges
    assert np.asarray(es2.W).tobytes() == np.asarray(es.W).tobytes()
    assert (es2.reopts, es2.adopted, es2.drops) == (
        es.reopts, es.adopted, es.drops)


# --- crash-safe resume (SIGKILL subprocess) --------------------------------

TRAIN = [sys.executable, "-m", "repro.launch.train", "--arch", "smollm-135m",
         "--reduced", "--workers", "4", "--steps", "10", "--batch", "1",
         "--seq", "16", "--topo", "ring", "--elastic", "--drift-step", "4",
         "--slow-nodes", "1", "--slow-bw", "1.0", "--churn-events", "1",
         "--ckpt-every", "3", "--log-every", "1", "--seed", "0"]


def run_train(extra, cwd, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(cwd, "src")
    return subprocess.run(TRAIN + extra, env=env, capture_output=True,
                          text=True, timeout=timeout, cwd=cwd)


def losses_by_step(json_path):
    with open(json_path) as f:
        hist = json.load(f)["history"]
    return {h["step"]: (h["loss"], h["consensus_err"]) for h in hist}


@pytest.mark.slow
def test_sigkill_resume_reproduces_loss_curve_bit_exactly():
    """Kill the elastic trainer with SIGKILL mid-run; ``--resume`` must
    replay from the last checkpoint and reproduce the uninterrupted loss /
    consensus curve bit-exactly (shortest-roundtrip floats in the history
    json are injective, so string-equal ⇔ bit-equal)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with tempfile.TemporaryDirectory() as d:
        full = run_train(["--json-out", f"{d}/full.json",
                          "--ckpt-dir", f"{d}/ck_full"], repo)
        assert full.returncode == 0, full.stdout + full.stderr
        ref = losses_by_step(f"{d}/full.json")
        assert set(ref) == set(range(10))

        killed = run_train(["--ckpt-dir", f"{d}/ck", "--kill-at-step", "8"],
                           repo)
        assert killed.returncode == -signal.SIGKILL
        assert os.listdir(f"{d}/ck")        # a checkpoint survived the crash

        resumed = run_train(["--ckpt-dir", f"{d}/ck", "--resume",
                             "--json-out", f"{d}/resumed.json"], repo)
        assert resumed.returncode == 0, resumed.stdout + resumed.stderr
        assert "resumed from step" in resumed.stdout
        got = losses_by_step(f"{d}/resumed.json")
        assert got, "resumed run logged nothing"
        for step, vals in got.items():      # overlap + tail, all bit-exact
            assert vals == ref[step], (step, vals, ref[step])
        assert max(got) == 9                # ran to completion


# --- sharded (ppermute) elastic path ---------------------------------------

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import make_baseline
from repro.core.graph import weight_matrix_from_weights
from repro.dsgd import (degrade_matrix, gossip_shard_elastic, gossip_sim,
                        schedule_from_topology, schedule_weight_arrays)

n = 8
mesh = jax.make_mesh((n,), ("data",))
topo = make_baseline("exponential", n)
sched = schedule_from_topology(topo)
W = jnp.asarray(weight_matrix_from_weights(n, topo.edges, topo.g), jnp.float32)
w_self, w_recv = (jnp.asarray(a) for a in schedule_weight_arrays(sched))
x = jax.random.normal(jax.random.PRNGKey(0), (n, 6, 32))

def worker(xs, mix, ws, wr):
    return gossip_shard_elastic(xs, sched, "data", mix, ws, wr)

g = jax.shard_map(worker, mesh=mesh, in_specs=(P("data"), P(), P(), P()),
                  out_specs=P("data"), axis_names={"data"}, check_vma=False)

# fault-free: bit-exact vs the dense matmul path's own elastic oracle
ones = jnp.ones((n,), jnp.float32)
with jax.set_mesh(mesh):
    out = jax.jit(g)(x, ones, w_self, w_recv)
expect = gossip_sim(x, W)
np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-6)
print("ELASTIC_SHARD_FAULTFREE_OK")

# one non-participant: participants match the degraded dense mix exactly
mix = ones.at[3].set(0.0)
with jax.set_mesh(mesh):
    out = jax.jit(g)(x, mix, w_self, w_recv)
Wd = degrade_matrix(W, mix, jnp.ones((n, n), jnp.float32))
expect = gossip_sim(x, Wd)
live = np.asarray(mix) > 0
np.testing.assert_allclose(np.asarray(out)[live], np.asarray(expect)[live],
                           atol=1e-5)
print("ELASTIC_SHARD_DEGRADED_OK")

# elastic sharded TRAIN step: fault-free bit-parity with the plain sharded
# step, and a dead worker freezes its params on device
from repro.configs import get_arch, reduced_for_smoke
from repro.data import DataConfig, synthetic_lm_batch
from repro.dsgd import (init_dsgd_state, make_elastic_sharded_train_step,
                        make_sharded_train_step)
from repro.optim import sgd_momentum

cfg = reduced_for_smoke(get_arch("smollm-135m"))
opt_init, opt_update = sgd_momentum(0.05)
state = init_dsgd_state(jax.random.PRNGKey(0), cfg, n, opt_init)
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, batch_size=1)
per = [synthetic_lm_batch(dc, 0, node=i) for i in range(n)]
batch = {k: jnp.stack([b[k] for b in per]) for k in per[0]}

plain = make_sharded_train_step(cfg, sched, opt_update, mesh,
                                gossip_axes=("data",))
elastic = make_elastic_sharded_train_step(cfg, sched, opt_update, mesh,
                                          gossip_axes=("data",))
with jax.set_mesh(mesh):
    s1, m1 = jax.jit(plain)(state, batch)
    s2, m2 = jax.jit(elastic)(state, batch, ones, ones, w_self, w_recv)
assert np.asarray(m1["loss"]).tobytes() == np.asarray(m2["loss"]).tobytes()
for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
    assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
print("ELASTIC_SHARD_STEP_PARITY_OK")

dead = ones.at[5].set(0.0)
with jax.set_mesh(mesh):
    s3, m3 = jax.jit(elastic)(state, batch, dead, dead, w_self, w_recv)
for a, b in zip(jax.tree.leaves(s3.params), jax.tree.leaves(state.params)):
    assert np.asarray(a[5]).tobytes() == np.asarray(b[5]).tobytes()
for a, b in zip(jax.tree.leaves(s3.opt), jax.tree.leaves(state.opt)):
    assert np.asarray(a[5]).tobytes() == np.asarray(b[5]).tobytes()
assert np.isfinite(float(m3["loss"]))
print("ELASTIC_SHARD_FREEZE_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(
    not (hasattr(jax, "set_mesh") and hasattr(jax, "shard_map")),
    reason="requires the jax>=0.6 top-level set_mesh/shard_map APIs")
def test_elastic_sharded_path():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=repo)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for marker in ("ELASTIC_SHARD_FAULTFREE_OK", "ELASTIC_SHARD_DEGRADED_OK",
                   "ELASTIC_SHARD_STEP_PARITY_OK", "ELASTIC_SHARD_FREEZE_OK"):
        assert marker in res.stdout, res.stdout + "\n" + res.stderr
