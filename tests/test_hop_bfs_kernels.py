"""hop_bfs Pallas kernel vs the pure-jnp oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.hop_bfs import ops, ref
from repro.kernels.hop_bfs.kernel import LANE, SUBLANE, hop_step_2d


def _random_adj(n, p, rng):
    up = rng.random((n, n)) < p
    adj = np.triu(up, 1)
    return adj | adj.T


@pytest.mark.parametrize("n,p", [(4, 0.6), (8, 0.4), (16, 0.25), (63, 0.1),
                                 (64, 0.1), (129, 0.05)])
def test_hop_step_kernel_matches_ref(n, p):
    rng = np.random.default_rng(n)
    adj = jnp.asarray(_random_adj(n, p, rng))
    reach = jnp.eye(n, dtype=bool) | adj
    new_ref, cnt_ref = ref.hop_step(reach, adj)
    new_k, cnt_k = ops.hop_step(reach, adj, use_kernel=True)
    assert (np.asarray(new_k) == np.asarray(new_ref)).all()
    assert int(cnt_k) == int(cnt_ref) == int(np.asarray(new_ref).sum())


def test_hop_step_fallback_below_two_nodes():
    reach = jnp.ones((1, 1), dtype=bool)
    adj = jnp.zeros((1, 1), dtype=bool)
    new, cnt = ops.hop_step(reach, adj, use_kernel=True)
    assert bool(new[0, 0]) and int(cnt) == 1


def test_hop_step_monotone_and_fixed_point():
    """reach only grows, and a saturated reach matrix is a fixed point."""
    rng = np.random.default_rng(0)
    adj = jnp.asarray(_random_adj(24, 0.15, rng))
    reach = jnp.eye(24, dtype=bool) | adj
    for _ in range(24):
        new, _ = ops.hop_step(reach, adj, use_kernel=True)
        assert bool(jnp.all(reach <= new))  # monotone
        reach = new
    again, cnt = ops.hop_step(reach, adj, use_kernel=True)
    assert (np.asarray(again) == np.asarray(reach)).all()
    assert int(cnt) == int(np.asarray(reach).sum())


def test_hop_step_2d_padding_is_inert():
    """Zero-padded rows/columns contribute nothing to matmul, OR, counts."""
    n = 20
    rng = np.random.default_rng(3)
    adj = _random_adj(n, 0.2, rng)
    reach = np.eye(n, dtype=bool) | adj
    r_pad = -(-n // SUBLANE) * SUBLANE
    c_pad = -(-n // LANE) * LANE
    Rp = np.zeros((r_pad, c_pad), np.float32)
    Ap = np.zeros((c_pad, c_pad), np.float32)
    Rp[:n, :n] = reach
    Ap[:n, :n] = adj
    new, cnt = hop_step_2d(jnp.asarray(Rp), jnp.asarray(Ap))
    new = np.asarray(new)
    exp, _ = ref.hop_step(jnp.asarray(reach), jnp.asarray(adj))
    assert (new[:n, :n] > 0).astype(bool).tolist() == np.asarray(exp).tolist()
    assert not new[n:, :].any() and not new[:, n:].any()
    assert int(np.asarray(cnt)[:n, 0].sum()) == int(np.asarray(exp).sum())
